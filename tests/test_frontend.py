"""repro.frontend: jaxpr capture -> TaskGraph -> solved whole-plan program.

Coverage contract:
* every supported primitive round-trips against the ``jax.jit`` oracle;
* a function containing unsupported primitives still executes end-to-end
  through opaque fallback partitioning (with coverage < 1);
* the trace cache shares lowerings (and graphs) across identical traces;
* a ``repro.models`` FFN block and a >=3-matmul chain execute correctly on
  both the ``xla`` and ``pallas_interpret`` impls (the acceptance bar);
* traced workloads serve through ``PlanEngine.register_function``.
"""
from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import frontend
from repro.codegen import OPAQUE_PREFIX
from repro.core.solver import SolverOptions, build_graph

OPTS = SolverOptions(time_budget_s=6.0)


def _arr(shape, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


def _roundtrip(fn, *args, impl=None, full_coverage=True, opts=OPTS):
    tf = frontend.trace(fn, *args)
    if full_coverage:
        assert tf.coverage.eqn_ratio == 1.0, tf.coverage.to_jsonable()
    tf.validate(impl=impl, plan=tf.solve(opts=opts))
    return tf


# ---------------------------------------------------------------------------
# Per-primitive round trips vs the jax.jit oracle
# ---------------------------------------------------------------------------
def test_dot_general_plain():
    _roundtrip(lambda a, b: a @ b, _arr((17, 23)), _arr((23, 11), 1))


def test_dot_general_batched():
    f = lambda a, b: jnp.einsum("bik,bkj->bij", a, b)   # noqa: E731
    _roundtrip(f, _arr((3, 8, 12)), _arr((3, 12, 6), 1))


def test_dot_general_multi_contract():
    f = lambda a, b: jnp.einsum("ikl,klj->ij", a, b)    # noqa: E731
    _roundtrip(f, _arr((7, 5, 6)), _arr((5, 6, 9), 1))


def test_elementwise_add_mul_sub():
    f = lambda a, b: (a + b) * a - b                    # noqa: E731
    _roundtrip(f, _arr((9, 14)), _arr((9, 14), 1))


def test_elementwise_scalar_and_neg():
    f = lambda a: -(a * 2.0) + 1.5                      # noqa: E731
    _roundtrip(f, _arr((6, 10)))


def test_broadcast_in_dim_vector_bias():
    f = lambda a, b: a + b                              # noqa: E731
    _roundtrip(f, _arr((12, 7)), _arr((7,), 1))


def test_broadcast_size1_dim():
    f = lambda a, b: a * b                              # noqa: E731
    _roundtrip(f, _arr((5, 8)), _arr((1, 8), 1))


def test_transpose():
    f = lambda a: a.T @ a                               # noqa: E731
    _roundtrip(f, _arr((13, 9)))


def test_transpose_3d():
    f = lambda a: jnp.transpose(a, (2, 0, 1))           # noqa: E731
    _roundtrip(f, _arr((4, 5, 6)))


def test_reduce_sum_axis():
    f = lambda a: a.sum(axis=0)                         # noqa: E731
    _roundtrip(f, _arr((11, 15)))


def test_reduce_sum_multi_axis():
    f = lambda a: a.sum(axis=(0, 2))                    # noqa: E731
    _roundtrip(f, _arr((5, 7, 6)))


def test_reduce_sum_to_scalar_goes_opaque():
    tf = frontend.trace(lambda a: a.sum() * a, _arr((6, 7)))
    assert tf.coverage.eqn_ratio < 1.0      # rank-0 result + its consumer
    tf.validate(plan=tf.solve(opts=OPTS))


def test_pjit_inlining_sees_through_jax_nn():
    x = _arr((8, 16))
    tf = frontend.trace(jax.nn.silu, x)
    # silu = x * logistic(x): both lower (logistic via the unary family)
    assert tf.coverage.eqn_ratio == 1.0
    assert any(s.op == "unary:logistic" for s in tf.graph.statements)
    tf.validate(plan=tf.solve(opts=OPTS))


# ---------------------------------------------------------------------------
# Fallback partitioning around unsupported primitives
# ---------------------------------------------------------------------------
def test_unsupported_primitive_fallback_partition():
    def fn(a, b):
        h = a @ b                         # supported
        h = jnp.sort(h, axis=0)           # opaque (data-dependent order)
        return h @ b.T                    # supported again

    a, b = _arr((10, 12)), _arr((12, 8), 1)
    tf = frontend.trace(fn, a, b)
    cov = tf.coverage
    assert cov.n_supported == 3 and cov.n_eqns == 4
    ops = [s.op for s in tf.graph.statements]
    assert any(op.startswith(OPAQUE_PREFIX) for op in ops)
    assert sum(op == "mul" for op in ops) == 2
    tf.validate(plan=tf.solve(opts=OPTS))


def test_fully_opaque_function_still_runs():
    fn = lambda a: jnp.flip(jnp.sort(a, axis=0), 1)     # noqa: E731
    tf = frontend.trace(fn, _arr((6, 4)))
    assert tf.coverage.eqn_ratio == 0.0
    tf.validate(plan=tf.solve(opts=OPTS))


def test_bf16_dot_lowers_with_widened_band():
    def fn(a):
        h = a.astype(jnp.bfloat16)
        return (h @ h.T).astype(jnp.float32)

    tf = frontend.trace(fn, _arr((6, 9)))
    assert tf.coverage.eqn_ratio == 1.0     # converts alias, bf16 dot lowers
    assert tf.record.precision_bytes == 2   # validate() widens to the band
    tf.validate(plan=tf.solve(opts=OPTS))


def test_output_consumed_downstream_is_still_returned():
    def fn(a, b):
        e = a @ b
        return e, e @ b.T         # e is both an output and consumed

    tf = frontend.trace(fn, _arr((7, 5)), _arr((5, 9), 1))
    tf.validate(plan=tf.solve(opts=OPTS))


def test_passthrough_and_constant_outputs():
    def fn(a):
        return a, jnp.float32(3.0), a @ a.T

    tf = frontend.trace(fn, _arr((5, 5)))
    out = tf.executable(opts=OPTS)(_arr((5, 5)))
    ref = jax.jit(fn)(_arr((5, 5)))
    for o, r in zip(jax.tree_util.tree_leaves(out),
                    jax.tree_util.tree_leaves(ref)):
        np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                                   rtol=2e-4, atol=2e-3)


def test_closure_consts_are_hoisted_and_bound_per_trace():
    w1 = _arr((6, 8), 3)
    w2 = _arr((6, 8), 4)

    def make(w):
        return lambda x: x @ (w * 1.0)

    tf1 = frontend.trace(make(w1), _arr((4, 6)))
    tf2 = frontend.trace(make(w2), _arr((4, 6)))
    # same structure -> same record/graph, different bound const values
    assert tf1.record is tf2.record
    tf1.validate(plan=tf1.solve(opts=OPTS))
    tf2.validate(plan=tf2.solve(opts=OPTS))
    x = _arr((4, 6), 5)
    o1 = tf1.executable(opts=OPTS)(x)
    o2 = tf2.executable(opts=OPTS)(x)
    assert not np.allclose(np.asarray(o1), np.asarray(o2))


# ---------------------------------------------------------------------------
# Trace cache
# ---------------------------------------------------------------------------
def test_trace_cache_identity_and_stats():
    frontend.clear_trace_cache()
    fn = lambda a, b: a @ b + b.sum(axis=0)             # noqa: E731
    args = (_arr((6, 7)), _arr((7, 9), 1))
    t1 = frontend.trace(fn, *args)
    before = frontend.trace_cache_stats()
    t2 = frontend.trace(fn, *args)
    after = frontend.trace_cache_stats()
    assert t1.record is t2.record and t1.graph is t2.graph
    assert after["hits"] == before["hits"] + 1
    # different shapes -> different fingerprint -> new record
    t3 = frontend.trace(fn, _arr((3, 7)), _arr((7, 9), 1))
    assert t3.record is not t1.record
    assert t3.graph.name != t1.graph.name


def test_trace_cache_shares_solved_plan():
    fn = lambda a: a @ a.T                              # noqa: E731
    t1 = frontend.trace(fn, _arr((8, 6)))
    p1 = t1.solve()
    t2 = frontend.trace(fn, _arr((8, 6)))
    assert t2.solve() is p1


def test_trace_cache_eviction_releases_opaque_registry():
    from repro.codegen.reference import opaque_fn
    frontend.clear_trace_cache()
    cache = frontend.trace_cache()
    old_cap = cache.capacity
    try:
        cache.resize(1)
        t1 = frontend.trace(lambda a: jnp.sort(a, axis=0) @ a, _arr((5, 5)))
        ops = t1.record.opaque_ops
        assert ops and all(opaque_fn(op) for op in ops)
        # a second distinct trace evicts the first record -> its opaque
        # callables leave the registry with it
        frontend.trace(lambda a: jnp.flip(a, 0) @ a, _arr((5, 5)))
        with pytest.raises(KeyError, match="re-trace"):
            opaque_fn(ops[0])
        # re-tracing re-registers identical semantics
        t3 = frontend.trace(lambda a: jnp.sort(a, axis=0) @ a, _arr((5, 5)))
        assert t3.record.opaque_ops == ops
        assert all(opaque_fn(op) for op in ops)
    finally:
        cache.resize(old_cap)


def test_build_graph_resolves_traced_names():
    fn = lambda a: a @ a.T                              # noqa: E731
    tf = frontend.trace(fn, _arr((8, 6)))
    assert build_graph(tf.graph.name) is tf.graph
    with pytest.raises(KeyError):
        frontend.traced_graph("traced:0000000000000000")


def test_argument_contract_errors():
    fn = lambda a, b: a @ b                             # noqa: E731
    tf = frontend.trace(fn, _arr((6, 7)), _arr((7, 9), 1))
    exe = tf.executable(opts=OPTS)
    with pytest.raises(ValueError, match="re-trace"):
        exe(_arr((5, 7)), _arr((7, 9)))
    with pytest.raises(TypeError):
        exe(_arr((6, 7)))


# ---------------------------------------------------------------------------
# Acceptance: FFN block + >=3-matmul chain on both impls
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
def test_matmul_chain_both_impls(impl):
    def chain(a, b, c, d):
        return ((a @ b) @ c) @ d

    args = (_arr((24, 32)), _arr((32, 20), 1), _arr((20, 28), 2),
            _arr((28, 16), 3))
    tf = frontend.trace(chain, *args)
    assert tf.coverage.eqn_ratio == 1.0
    plan = tf.solve(opts=OPTS)
    tf.validate(*args, impl=impl, plan=plan)


@pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
def test_models_ffn_block_both_impls(impl):
    from repro.models import ffn
    params = ffn.init_swiglu(jax.random.PRNGKey(0), 32, 64)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 32), jnp.float32)

    def block(p, v):
        return ffn.swiglu(p, v, compute_dtype=jnp.float32)

    tf = frontend.trace(block, params, x)
    # the three projection matmuls, the gating mul AND silu's logistic are
    # all owned by the solver — nothing is opaque
    assert tf.coverage.eqn_ratio == 1.0
    assert tf.coverage.flop_ratio == 1.0
    plan = tf.solve(opts=OPTS)
    tf.validate(impl=impl, plan=plan)


def test_models_gelu_mlp_block():
    from repro.models import ffn
    params = ffn.init_gelu(jax.random.PRNGKey(0), 24, 48)
    x = jax.random.normal(jax.random.PRNGKey(1), (6, 24), jnp.float32)

    def block(p, v):
        return ffn.gelu_mlp(p, v, compute_dtype=jnp.float32)

    tf = frontend.trace(block, params, x)
    assert tf.coverage.flop_ratio > 0.9
    tf.validate(plan=tf.solve(opts=OPTS))


# ---------------------------------------------------------------------------
# Segment fusion, matmul-chain reassociation and the cost-model band
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
def test_traced_pointwise_chain_collapses_to_one_segment(impl):
    """A dot followed by a single-consumer pointwise tail (which lowers as
    separate tasks) must fuse into ONE compiled segment — no
    materialization boundary between the contraction and its tail."""
    def fn(a, b):
        return jnp.tanh(a @ b) * 2.0 + 1.0

    args = (_arr((24, 20)), _arr((20, 16), 1))
    tf = frontend.trace(fn, *args)
    assert tf.coverage.eqn_ratio == 1.0
    plan = tf.solve(opts=OPTS)
    exe = tf.executable(plan=plan, impl=impl)
    assert exe.executor.program(impl).n_segments == 1
    tf.validate(*args, impl=impl, plan=plan)


def test_matmul_chain_reassociation_reduces_flops():
    """A user-written left-associated chain with a DP-better parenthesization
    is rewritten at lowering time: fewer statement flops, same numerics."""
    def chain(a, b, c):
        return (a @ b) @ c

    # left-assoc: 100*10*50 + 100*50*5 = 75k MACs;
    # a @ (b @ c): 10*50*5 + 100*10*5 = 7.5k MACs
    args = (_arr((100, 10)), _arr((10, 50), 1), _arr((50, 5), 2))
    tf = frontend.trace(chain, *args)
    assert tf.coverage.eqn_ratio == 1.0
    stmts = tf.graph.statements
    assert any("_ra" in s.name for s in stmts)
    macs = sum(int(np.prod(list(s.trip_counts.values())))
               for s in stmts)
    assert macs == 7500
    tf.validate(*args, plan=tf.solve(opts=OPTS))


def test_reassociation_keeps_returned_intermediates():
    """An intermediate that the function RETURNS is protected: the rewrite
    must not dissolve it, and both outputs still match the oracle."""
    def fn(a, b, c):
        h = a @ b
        return h, h @ c

    args = (_arr((100, 10)), _arr((10, 50), 1), _arr((50, 5), 2))
    tf = frontend.trace(fn, *args)
    tf.validate(*args, plan=tf.solve(opts=OPTS))


def test_model_latency_within_sane_band():
    """The calibrated cost model's prediction for a fully covered workload
    stays within a wide sanity band of measured steady-state — catches
    unit mistakes (us-vs-s) and uncalibrated-constant regressions, not
    model accuracy (the host is shared and noisy)."""
    import time

    from repro.calibrate import calibrate

    # cached full profile when the host is calibrated; one quick (seconds)
    # microbench pass otherwise — never persisted by the test
    hw = calibrate(quick=True, save=False).hardware()

    def chain(a, b, c, d):
        return ((a @ b) @ c) @ d

    args = (_arr((160, 192)), _arr((192, 144), 1), _arr((144, 176), 2),
            _arr((176, 128), 3))
    tf = frontend.trace(chain, *args)
    assert tf.coverage.eqn_ratio == 1.0
    plan = tf.solve(hw=hw, opts=OPTS)
    exe = tf.executable(plan=plan, impl="xla")
    jax.block_until_ready(exe(*args))
    best = float("inf")
    for _ in range(5):
        t0 = time.perf_counter()
        for _ in range(20):
            out = exe(*args)
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / 20)
    model_ratio = plan.latency_s / best
    assert 0.02 <= model_ratio <= 50.0, (plan.latency_s, best)


# ---------------------------------------------------------------------------
# Serving integration
# ---------------------------------------------------------------------------
def test_plan_engine_register_function_serves_and_warms():
    from repro.serve import PlanEngine

    a, b = _arr((16, 24)), _arr((24, 12), 1)

    def fn(x, y):
        return jnp.tanh(x @ y) @ y.T

    eng = PlanEngine(impl="xla")
    tf = eng.register_function("fn", fn, (a, b), solver_opts=OPTS)
    assert "fn" in eng.names()
    eng.warmup("fn", (a, b))
    out = eng.submit("fn", (a, b))
    ref = jax.jit(fn)(a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-3)
    st = eng.stats()
    assert st["functions"] == ["fn"]
    assert st["per_name"]["fn"] >= 2
    # dict-of-arrays submission still works for function entries
    env = tf.bind_args((a, b))
    raw = eng.submit("fn", env)
    assert set(raw) == set(tf.graph.final_outputs())
    eng.unregister("fn")
    assert eng.stats()["functions"] == []


def test_register_function_rejects_empty_graph():
    from repro.serve import PlanEngine, ServeConfig
    # strict mode surfaces the unservable function to the caller
    eng = PlanEngine(impl="xla", sc=ServeConfig(fallback=False))
    with pytest.raises(ValueError, match="empty graph"):
        eng.register_function("id", lambda x: x, (_arr((4, 4)),))
    # default (graceful) mode registers the plain-jit fallback instead —
    # the resilience contract in tests/test_ft_serve.py pins the rest
    eng2 = PlanEngine(impl="xla")
    assert eng2.register_function("id", lambda x: x,
                                  (_arr((4, 4)),)) is None
    assert eng2.stats()["resilience"]["entries"]["id"]["state"] \
        == "fallback"
    eng2.shutdown()
