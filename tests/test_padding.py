"""Unit + property tests: padding (core/padding.py) — paper §2.1.6, Eqs. 1-3."""
from __future__ import annotations

from _hypothesis_compat import given, settings, st

from repro.core.padding import (TileOption, burst_width,
                                communication_padding, divisors,
                                pad_to_multiple, tile_options)


def test_divisors():
    assert divisors(12) == (1, 2, 3, 4, 6, 12)
    assert divisors(1) == (1,)
    assert divisors(190) == (1, 2, 5, 10, 19, 38, 95, 190)


def test_paper_listing1_unroll_factors():
    """Trip count 190 -> {1,2,5,10,19,38,95,190}; padded to 192 ->
    {1,2,3,4,6,8,12,16,24,32,48,64,96,192} become available."""
    no_pad = {t.tile for t in tile_options(190, max_pad=0)}
    assert no_pad == {1, 2, 5, 10, 19, 38, 95, 190}
    padded = {t.tile for t in tile_options(190, max_pad=2)}
    for f in (3, 4, 6, 8, 12, 16, 24, 32, 48, 64, 96, 192):
        assert f in padded, f
    # the pad=2 option for tile 8 pads exactly to 192
    opt8 = next(t for t in tile_options(190, max_pad=2) if t.tile == 8)
    assert opt8.padded_tc == 192 and opt8.pad == 2 and opt8.n_tiles == 24


def test_tile_option_properties():
    t = TileOption(tile=8, padded_tc=192, ori_tc=190)
    assert t.pad == 2
    assert t.n_tiles == 24
    assert 0 < t.waste < 0.02


@settings(max_examples=200, deadline=None)
@given(tc=st.integers(1, 2048), max_pad=st.integers(0, 64))
def test_tile_options_satisfy_eq1_eq2(tc, max_pad):
    """Eq. 1: tile divides the (possibly padded) trip count;
    Eq. 2: padding bounded by max_pad; minimal pad per tile size."""
    opts = tile_options(tc, max_pad=max_pad, max_tile=256)
    assert opts, "at least tile=1 must exist"
    seen = set()
    for t in opts:
        assert t.padded_tc % t.tile == 0            # Eq. 1
        assert 0 <= t.pad <= max_pad                # Eq. 2
        assert t.ori_tc == tc
        assert t.tile not in seen                   # unique per tile size
        seen.add(t.tile)
        # minimality: no smaller pad in range legalises this tile
        for pad in range(0, t.pad):
            assert (tc + pad) % t.tile != 0


@settings(max_examples=100, deadline=None)
@given(tc=st.integers(1, 512))
def test_no_padding_is_divisor_space(tc):
    opts = tile_options(tc, max_pad=0)
    assert {t.tile for t in opts} == set(divisors(tc))
    assert all(t.pad == 0 for t in opts)


def test_burst_width_eq3():
    """Paper Fig. 1 example: row of 190 floats -> 2-wide (64-bit) bursts;
    192 -> 16-wide (512-bit)."""
    assert burst_width(190) == 2
    assert burst_width(192) == 16
    assert burst_width(191) == 1
    assert burst_width(32) == 16


def test_communication_padding_fig1():
    padded, b = communication_padding(190)
    assert (padded, b) == (192, 16)
    padded, b = communication_padding(192)
    assert (padded, b) == (192, 16)
    # bounded padding cannot reach 16 -> best effort
    padded, b = communication_padding(191, max_pad=0)
    assert (padded, b) == (191, 1)


def test_pad_to_multiple():
    assert pad_to_multiple(190, 128) == 256
    assert pad_to_multiple(256, 128) == 256
    assert pad_to_multiple(1, 8) == 8


@settings(max_examples=100, deadline=None)
@given(n=st.integers(1, 4096))
def test_communication_padding_monotone(n):
    padded, b = communication_padding(n)
    assert padded >= n
    assert padded % b == 0
    assert b >= burst_width(n)
