"""Codegen subsystem: the plan-lowered executors (whole-program and
per-task) are numerically equivalent to the statement-order reference
oracle, the plan's decisions (tiles, permutation, fusion, padding)
demonstrably reach the generated kernels, and the whole-plan engine
(wave schedule, program cache, no-retrace steady state) behaves as
specified.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import pytest

from repro.codegen import (assert_close, plan_executor, random_inputs,
                           reference_executor, wave_schedule)
from repro.core import SolverOptions, THREE_SLICE, polybench, solve
from repro.core.fusion import fuse
from repro.kernels import kernel_impl
from repro.kernels.contraction import ContractionSpec, LoopDim, Operand
from repro.kernels.contraction import ops as contraction_ops

# Every graph with density == 1.0 statements (triangular kernels are
# cost-modeled only).
EXECUTABLE = ["3mm", "2mm", "gemm", "atax", "bicg", "mvt", "gesummv",
              "gemver", "madd", "2-madd", "3-madd"]

_PLANS: dict[str, object] = {}


def _plan_for(name: str):
    if name not in _PLANS:
        g = polybench.build(name)
        _PLANS[name] = (g, solve(g, THREE_SLICE,
                                 SolverOptions(time_budget_s=6.0)))
    return _PLANS[name]


# ---------------------------------------------------------------------------
# Equivalence: whole-program AND per-task executors vs oracle, both impls
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
@pytest.mark.parametrize("name", EXECUTABLE)
def test_lowered_executor_matches_oracle(name, impl):
    g, plan = _plan_for(name)
    ins = random_inputs(g, seed=1)
    ref = reference_executor(g)(ins)
    prog_exe = plan_executor(g, plan)                      # whole-program
    task_exe = plan_executor(g, plan, mode="per_task")     # debug path
    with kernel_impl(impl):
        out = prog_exe(ins)
        out_pt = task_exe(ins)
    assert set(out) == set(ref) == set(g.final_outputs())
    for k in ref:
        assert_close(out[k], ref[k], name=f"{name}[{impl}]:{k}")
        # the two executors agree with each other, not just with the oracle
        assert_close(out[k], out_pt[k],
                     name=f"{name}[{impl}]:{k} program-vs-per_task")


# ---------------------------------------------------------------------------
# Plan-faithfulness: the solver's tiles/permutation reach the Pallas grid
# ---------------------------------------------------------------------------
def test_plan_tiles_reach_pallas_grid():
    g, plan = _plan_for("gemm")
    exe = plan_executor(g, plan)
    lw = exe.lowerings("pallas_interpret")[0]
    assert lw.kind == "contraction"
    (unit,) = lw.units
    spec = unit.spec
    cfg = plan.configs[0]
    # grid order is exactly the plan permutation
    assert spec.loop_names == tuple(cfg.perm)
    # one grid dim per loop, extent = padded trip count / plan tile
    for dim, loop in zip(spec.loops, cfg.perm):
        opt = cfg.tiles[loop]
        assert dim.tile == opt.tile
        assert dim.padded == opt.padded_tc
        assert dim.n_tiles == opt.padded_tc // opt.tile
    assert lw.grid == tuple(cfg.tiles[l].n_tiles for l in cfg.perm)
    # reduction loop innermost, as the solver pins it
    assert spec.reduction == (cfg.perm[-1],)


def test_fusion_becomes_single_kernel():
    """init + accumulate statements lower to ONE kernel invocation whose
    accumulator is seeded by the init value."""
    g, plan = _plan_for("gemver")
    exe = plan_executor(g, plan)
    lows = exe.lowerings("xla")
    # the x task fuses x_init (reads z) with x_mac (A^T y accumulation)
    x_task = next(lw for lw in lows.values() if lw.out_array == "x")
    assert len(x_task.units) == 1
    spec = x_task.units[0].spec
    assert spec.init_reads == (Operand("z", ("j1",)),)
    assert len(x_task.units[0].statements) == 2


def test_non_matmul_contractions_use_generalized_kernel():
    """Transposed reads (mvt x2: A[j,i]) and 3+ operand statements
    (gemver Ah: A*u1*v1*u2*v2) lower through the generalized Pallas kernel,
    not the einsum fallback — and validate in interpret mode."""
    for name, out_array, min_reads in (("mvt", "x2", 2), ("gemver", "Ah", 5)):
        g, plan = _plan_for(name)
        exe = plan_executor(g, plan)
        lows = exe.lowerings("pallas_interpret")
        lw = next(l for l in lows.values() if l.out_array == out_array)
        assert lw.kind == "contraction", f"{name}:{out_array} fell back"
        spec = lw.units[-1].spec
        assert len(spec.reads) >= min_reads
        ins = random_inputs(g, seed=2)
        ref = reference_executor(g)(ins)
        with kernel_impl("pallas_interpret"):
            out = exe(ins)
        for k in ref:
            assert_close(out[k], ref[k], name=f"{name}:{k}")
    # mvt's x2 statement really reads A transposed
    g, _ = _plan_for("mvt")
    x2_mac = next(s for s in g.statements if s.name == "x2_mac")
    assert any(tuple(a.iters) == ("j1", "i1") for a in x2_mac.reads)


def test_padding_applied_and_sliced_back():
    """A plan tile that does not divide the extent pads the grid and slices
    the output back to the original shape."""
    spec = ContractionSpec(
        loops=(LoopDim("i", 64, 192, 180), LoopDim("j", 64, 192, 190),
               LoopDim("k", 64, 256, 200)),
        reduction=("k",), op="mul",
        reads=(Operand("A", ("i", "k")), Operand("B", ("k", "j"))),
        out_iters=("i", "j"))
    assert spec.grid == (3, 3, 4)
    rng = np.random.default_rng(0)
    A = rng.normal(size=(180, 200)).astype(np.float32)
    B = rng.normal(size=(200, 190)).astype(np.float32)
    out = contraction_ops.contract(spec, A, B, impl="pallas_interpret")
    assert out.shape == (180, 190)
    assert_close(out, A @ B, name="padded gemm")


def test_add_op_with_reduction_counts_terms_once():
    """op='add' with a reduction loop: an operand missing the reduction
    iterator must be counted once, not once per reduction block."""
    spec = ContractionSpec(
        loops=(LoopDim("i", 4, 8, 8), LoopDim("j", 4, 8, 8)),
        reduction=("j",), op="add",
        reads=(Operand("A", ("i", "j")), Operand("b", ("i",))),
        out_iters=("i",))
    rng = np.random.default_rng(3)
    A = rng.normal(size=(8, 8)).astype(np.float32)
    b = rng.normal(size=(8,)).astype(np.float32)
    expect = A.sum(axis=1) + b          # b projected exactly once
    out_ref = contraction_ops.contract(spec, A, b, impl="xla")
    out_pl = contraction_ops.contract(spec, A, b, impl="pallas_interpret")
    assert_close(out_ref, expect, name="add-red xla")
    assert_close(out_pl, expect, name="add-red interpret")


def test_spec_rejects_non_innermost_reduction():
    """The kernel's accumulator needs reduction grid dims innermost; a spec
    violating that must fail loudly, not compute garbage."""
    with pytest.raises(ValueError, match="innermost"):
        ContractionSpec(
            loops=(LoopDim("k", 4, 8, 8), LoopDim("i", 4, 8, 8),
                   LoopDim("j", 4, 8, 8)),
            reduction=("k",), op="mul",
            reads=(Operand("A", ("i", "k")), Operand("B", ("k", "j"))),
            out_iters=("i", "j"))


def test_transposed_self_read_refused():
    """C[i,j] = A[i,j] * C[j,i] carries a loop dependence neither the kernel
    nor the oracle executes faithfully — lowering must raise."""
    from repro.core import Access, Array, Statement, TaskGraph
    g = TaskGraph(
        name="selfT",
        arrays={"A": Array("A", (8, 8)), "C": Array("C", (8, 8))},
        statements=[Statement(
            name="upd", loops=("i", "j"), trip_counts={"i": 8, "j": 8},
            reads=(Access("A", ("i", "j")), Access("C", ("j", "i"))),
            writes=(Access("C", ("i", "j")),))])
    plan = solve(g, THREE_SLICE, SolverOptions(time_budget_s=2.0))
    with pytest.raises(NotImplementedError, match="non-write"):
        plan_executor(g, plan)(random_inputs(g))


def test_buffering_decision_reaches_kernel():
    """placements' buffer counts drive the spec's overlap semantics."""
    g, plan = _plan_for("gemm")
    cfg = plan.configs[0]
    exe = plan_executor(g, plan)
    spec = exe.lowerings("xla")[0].units[0].spec
    reads = [a for a in ("A", "B") if a in cfg.placements]
    overlapped = all(cfg.placements[a].buffers >= 2 for a in reads)
    assert spec.buffers == (2 if overlapped else 1)


# ---------------------------------------------------------------------------
# Whole-plan engine: wave schedule, program cache, no-retrace steady state
# ---------------------------------------------------------------------------
def test_3mm_wave_schedule_concurrency():
    """3mm's two independent matmuls land in the SAME wave; assigned to
    distinct slices they form concurrent groups, and the cross-slice edge
    into the final matmul is scheduled to overlap the next wave."""
    g, plan = _plan_for("3mm")
    # pin the schedule's input: E on slice 0, F on slice 1, G on slice 0
    # (the schedule mechanism is under test, not the solver's assignment)
    cfgs = {tid: dataclasses.replace(cfg, slice_id=tid % 2)
            for tid, cfg in plan.configs.items()}
    plan2 = dataclasses.replace(plan, configs=cfgs)
    ws = wave_schedule(fuse(g), plan2)
    assert ws.waves == ((0, 1), (2,))               # E,F concurrent; G after
    assert ws.wave_of[0] == ws.wave_of[1] == 0
    assert ws.slice_of[0] != ws.slice_of[1]         # distinct slices
    groups = ws.concurrent_groups(0)
    assert len(groups) == 2 and groups[0] == (0,) and groups[1] == (1,)
    # F crosses slice 1 -> slice 0: issued at wave 0, needed at wave 1
    (tr,) = [t for t in ws.transfers if t.array == "F"]
    assert (tr.ready_wave, tr.need_wave, tr.overlap_waves) == (0, 1, 1)
    # liveness: E and F die at their last consumer G (tid 2)
    assert ws.last_reader["E"] == 2 and ws.last_reader["F"] == 2
    assert set(ws.dead_after[2]) == {"E", "F"}


def test_program_second_call_retraces_nothing():
    """Steady state: a second call with identical shapes/dtypes re-traces
    nothing — the whole-plan program is compiled exactly once."""
    g, plan = _plan_for("2mm")
    exe = plan_executor(g, plan, impl="xla")
    ins = random_inputs(g, seed=3)
    out1 = exe(ins)
    prog = exe.program("xla")
    traces = prog.trace_count
    assert traces == 1
    out2 = exe(ins)                                 # identical signature
    assert prog.trace_count == traces
    for k in out1:
        assert_close(out1[k], out2[k], name=f"2mm steady:{k}")


def test_program_cache_shared_across_executables():
    """Two executables for the same (graph, plan, impl) share ONE compiled
    program — the serving path pays zero re-lowering/re-tracing."""
    g, plan = _plan_for("2mm")
    a = plan_executor(g, plan, impl="xla")
    b = plan_executor(g, plan, impl="xla")
    assert a.program("xla") is b.program("xla")
    # a fresh but content-identical graph hits the same cache entry
    g2 = polybench.build("2mm")
    c = plan_executor(g2, plan, impl="xla")
    assert c.program("xla") is a.program("xla")


def test_wave_order_is_topological():
    """The wave-major execution order respects every dataflow edge."""
    for name in ("3mm", "gemver", "atax"):
        g, plan = _plan_for(name)
        fg = fuse(g)
        ws = wave_schedule(fg, plan)
        pos = {tid: i for i, tid in enumerate(ws.order)}
        for (u, v, _) in fg.edges:
            assert pos[u] < pos[v]
        for (u, v, _) in fg.edges:
            assert ws.wave_of[u] < ws.wave_of[v]


# ---------------------------------------------------------------------------
# Dataflow execution: slice-aware dispatch across multiple devices
# ---------------------------------------------------------------------------
def test_multi_device_slice_dispatch():
    """With several JAX devices, tasks run on their slice's device and
    cross-slice edges transfer — in BOTH executor modes (whole-program
    placement inside the jit, and the per-task path's overlap-aware
    transfers + liveness pops + forced donation); results match the
    oracle.  Slice diversity is pinned so the multi-device branches run
    regardless of what the solver picked."""
    from conftest import run_subprocess
    code = """
import dataclasses, os
os.environ["REPRO_DONATE"] = "1"    # exercise the donation path too
import numpy as np
import jax
from repro.codegen import (allclose, plan_executor, random_inputs,
                           reference_executor)
from repro.core import SolverOptions, THREE_SLICE, polybench, solve

assert len(jax.devices()) == 3, jax.devices()
g = polybench.build("3mm")
plan = solve(g, THREE_SLICE, SolverOptions(time_budget_s=6.0))
cfgs = {tid: dataclasses.replace(cfg, slice_id=tid % 3)
        for tid, cfg in plan.configs.items()}
plan = dataclasses.replace(plan, configs=cfgs)
ins = random_inputs(g, seed=1)
ref = reference_executor(g)(ins)
for mode in ("program", "per_task"):
    exe = plan_executor(g, plan, impl="xla", mode=mode)
    assert exe._multi if mode == "per_task" else exe.program("xla")._multi
    out = exe(ins)
    assert all(allclose(out[k], ref[k]) for k in ref), f"{mode} mismatch"
    out2 = exe(ins)                 # repeated call: donation must not
    assert all(allclose(out2[k], ref[k]) for k in ref)  # poison reuse
slices = {lw.slice_id for lw in exe.lowerings("xla").values()}
print("OK", sorted(slices))
"""
    res = run_subprocess(code, n_devices=3, timeout=300)
    assert res.returncode == 0, res.stderr
    assert "OK [0, 1, 2]" in res.stdout
