"""Back-compat: the deprecated ``repro.core.apply`` shim still works and the
plan executor it re-exports computes the same function as the reference.

(The codegen subsystem's own coverage lives in test_codegen.py; this file
keeps the legacy import path honest.)
"""
from __future__ import annotations

import warnings

import pytest

from repro.core import SolverOptions, THREE_SLICE, polybench, solve

with warnings.catch_warnings():
    warnings.simplefilter("ignore", DeprecationWarning)
    from repro.core.apply import (assert_close, plan_executor, random_inputs,
                                  reference_executor)

# triangular-density kernels are cost-modeled only (codegen raises)
EXECUTABLE = ["3mm", "2mm", "gemm", "atax", "bicg", "mvt", "gesummv",
              "gemver", "madd", "2-madd", "3-madd"]


def test_shim_emits_deprecation_warning():
    import importlib
    import repro.core.apply as shim
    with pytest.warns(DeprecationWarning):
        importlib.reload(shim)


@pytest.mark.parametrize("name", ["3mm", "atax"])
def test_plan_executor_matches_reference_via_shim(name):
    g = polybench.build(name)
    plan = solve(g, THREE_SLICE, SolverOptions(time_budget_s=8.0))
    ins = random_inputs(g, seed=1)
    ref = reference_executor(g)(ins)
    out = plan_executor(g, plan)(ins)
    assert set(ref) == set(out) == set(g.final_outputs())
    for k in ref:
        assert_close(out[k], ref[k], name=k)


@pytest.mark.parametrize("mode", ["sisyphus", "streamhls", "autodse"])
def test_restricted_mode_plans_also_execute(mode):
    g = polybench.build("2mm")
    plan = solve(g, THREE_SLICE, SolverOptions(mode=mode, time_budget_s=8.0))
    ins = random_inputs(g, seed=2)
    ref = reference_executor(g)(ins)
    out = plan_executor(g, plan)(ins)
    for k in ref:
        assert_close(out[k], ref[k], name=k)


def test_triangular_kernels_raise_cleanly():
    g = polybench.build("syrk")
    plan = solve(g, THREE_SLICE, SolverOptions(time_budget_s=5.0))
    with pytest.raises(NotImplementedError):
        plan_executor(g, plan)(random_inputs(g))


def test_pallas_interpret_execution_path():
    """The lowered path runs the actual Pallas kernel bodies when the
    dispatch context selects interpret mode."""
    from repro.kernels import kernel_impl
    g = polybench.build("gemm")
    plan = solve(g, THREE_SLICE, SolverOptions(time_budget_s=5.0))
    ins = random_inputs(g, seed=3)
    ref = reference_executor(g)(ins)
    with kernel_impl("pallas_interpret"):
        out = plan_executor(g, plan)(ins)
    assert_close(out["Cout"], ref["Cout"], name="Cout")
