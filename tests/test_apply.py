"""Codegen equivalence: the plan executor computes the same function as the
naive reference for every executable PolyBench kernel x solver mode."""
from __future__ import annotations

import numpy as np
import pytest

from repro.core import SolverOptions, THREE_SLICE, polybench, solve
from repro.core.apply import plan_executor, random_inputs, reference_executor

# triangular-density kernels are cost-modeled only (apply raises)
EXECUTABLE = ["3mm", "2mm", "gemm", "atax", "bicg", "mvt", "gesummv",
              "gemver", "madd", "2-madd", "3-madd"]


@pytest.mark.parametrize("name", EXECUTABLE)
def test_plan_executor_matches_reference(name):
    g = polybench.build(name)
    plan = solve(g, THREE_SLICE, SolverOptions(time_budget_s=8.0))
    ins = random_inputs(g, seed=1)
    ref = reference_executor(g)(ins)
    out = plan_executor(g, plan)(ins)
    assert set(ref) == set(out) == set(g.final_outputs())
    for k in ref:
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(ref[k]),
                                   rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("mode", ["sisyphus", "streamhls", "autodse"])
def test_restricted_mode_plans_also_execute(mode):
    g = polybench.build("2mm")
    plan = solve(g, THREE_SLICE, SolverOptions(mode=mode, time_budget_s=8.0))
    ins = random_inputs(g, seed=2)
    ref = reference_executor(g)(ins)
    out = plan_executor(g, plan)(ins)
    for k in ref:
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(ref[k]),
                                   rtol=2e-4, atol=2e-4)


def test_triangular_kernels_raise_cleanly():
    g = polybench.build("syrk")
    plan = solve(g, THREE_SLICE, SolverOptions(time_budget_s=5.0))
    with pytest.raises(NotImplementedError):
        plan_executor(g, plan)(random_inputs(g))


def test_pallas_interpret_execution_path():
    """The tiled-matmul path runs the actual Pallas kernel bodies when the
    dispatch context selects interpret mode."""
    from repro.kernels import kernel_impl
    g = polybench.build("gemm")
    plan = solve(g, THREE_SLICE, SolverOptions(time_budget_s=5.0))
    ins = random_inputs(g, seed=3)
    ref = reference_executor(g)(ins)
    with kernel_impl("pallas_interpret"):
        out = plan_executor(g, plan)(ins)
    np.testing.assert_allclose(np.asarray(out["Cout"]),
                               np.asarray(ref["Cout"]), rtol=2e-4, atol=2e-4)
