"""Tests for the §Perf / feasibility features added beyond the baseline:
microbatched gradient accumulation, fp32-master mixed precision, blocked
decode attention, attention score-dtype / grouped-GQA levers, chunked
rwkv6 backward memory, and the dry-run regeneration ladder policy."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import smoke
from repro.models import model as M
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.train_step import train_step


def _setup(arch="qwen1.5-0.5b", **over):
    cfg = dataclasses.replace(smoke(get_config(arch)), n_layers=2,
                              remat=False, **over)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, cfg.vocab)
    labels = jax.random.randint(jax.random.PRNGKey(2), (8, 16), 0,
                                cfg.vocab)
    return cfg, params, toks, labels


# ---------------------------------------------------------------------------
# microbatching
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("k", [2, 4, 8])
def test_microbatched_step_matches_full_batch(k):
    """Gradient accumulation is the same optimizer step (fp32 accum)."""
    cfg, params, toks, labels = _setup(compute_dtype="float32")
    oc = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10,
                     min_lr_frac=1.0)
    p1, o1, m1 = train_step(params, init_opt_state(params), toks, labels,
                            cfg=cfg, opt_cfg=oc, microbatches=1)
    p2, o2, m2 = train_step(params, init_opt_state(params), toks, labels,
                            cfg=cfg, opt_cfg=oc, microbatches=k)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-4, atol=2e-5)


def test_microbatches_must_divide_batch():
    cfg, params, toks, labels = _setup()
    with pytest.raises(AssertionError):
        train_step(params, init_opt_state(params), toks, labels,
                   cfg=cfg, opt_cfg=AdamWConfig(), microbatches=3)


# ---------------------------------------------------------------------------
# fp32 master weights (bf16 params)
# ---------------------------------------------------------------------------
def test_bf16_params_track_fp32_training():
    losses = {}
    for pd in ("float32", "bfloat16"):
        cfg, params, toks, labels = _setup(param_dtype=pd)
        params = jax.tree.map(
            lambda x: x.astype(jnp.float32), params)   # same init values
        params = jax.tree.map(
            lambda x: x.astype(jnp.bfloat16 if pd == "bfloat16"
                               else jnp.float32), params)
        opt = init_opt_state(params)
        if pd == "bfloat16":
            assert opt.master is not None            # fp32 master exists
            for mw in jax.tree.leaves(opt.master):
                assert mw.dtype == jnp.float32
        else:
            assert opt.master is None
        oc = AdamWConfig(lr=5e-3, warmup_steps=1, total_steps=10)
        ls = []
        for _ in range(5):
            params, opt, m = train_step(params, opt, toks, labels,
                                        cfg=cfg, opt_cfg=oc)
            ls.append(float(m["loss"]))
        losses[pd] = ls
    # trajectories amplify rounding; require tracking, not equality
    for a, b in zip(losses["float32"], losses["bfloat16"]):
        assert a == pytest.approx(b, rel=2e-2)


def test_master_keeps_precision_at_tiny_lr():
    """Without a master, bf16 weights swallow tiny updates; the master
    accumulates them."""
    p = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    opt = init_opt_state(p)
    oc = AdamWConfig(lr=1e-5, warmup_steps=0, weight_decay=0.0,
                     min_lr_frac=1.0, grad_clip=1e9)
    g = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    from repro.train.optimizer import adamw_update
    master0 = float(opt.master["w"][0, 0])
    for _ in range(3):
        p, opt, _ = adamw_update(oc, p, g, opt)
    assert float(opt.master["w"][0, 0]) < master0   # master moved
    # and the running master is consistent with the bf16 projection
    assert float(p["w"][0, 0]) == pytest.approx(
        float(opt.master["w"][0, 0]), abs=0.01)


# ---------------------------------------------------------------------------
# blocked decode attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kv_dtype", ["float32", "int8"])
def test_blocked_decode_matches_unblocked(kv_dtype):
    cfg0 = dataclasses.replace(smoke(get_config("yi-34b")),
                               compute_dtype="float32",
                               kv_cache_dtype=kv_dtype)
    cfg1 = dataclasses.replace(cfg0, decode_chunk=8)
    params = M.init_params(cfg0, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(7), (2, 13), 0,
                              cfg0.vocab)
    _, cache = M.prefill(params, cfg0, toks[:, :12], max_len=32)
    l0, _ = M.decode_step(params, cfg0, cache, toks[:, 12])
    l1, _ = M.decode_step(params, cfg1, cache, toks[:, 12])
    tol = 1e-5 if kv_dtype == "float32" else 2e-2
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1),
                               rtol=tol, atol=tol)


def test_blocked_decode_unroll_equivalent():
    cfg0 = dataclasses.replace(smoke(get_config("qwen3-0.6b")),
                               compute_dtype="float32",
                               kv_cache_dtype="float32", decode_chunk=8)
    cfg1 = dataclasses.replace(cfg0, unroll_layers=True)
    params = M.init_params(cfg0, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 13), 0,
                              cfg0.vocab)
    _, cache = M.prefill(params, cfg0, toks[:, :12], max_len=32)
    l0, _ = M.decode_step(params, cfg0, cache, toks[:, 12])
    l1, _ = M.decode_step(params, cfg1, cache, toks[:, 12])
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# attention levers
# ---------------------------------------------------------------------------
def test_model_level_levers_preserve_function():
    """score bf16 / grouped GQA / bf16 FFN activations change numerics
    within bf16 tolerance only."""
    base = dataclasses.replace(smoke(get_config("yi-34b")),
                               compute_dtype="float32")
    params = M.init_params(base, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(5), (2, 24), 0,
                              base.vocab)
    h0 = M.forward(params, base, toks)
    for over in ({"gqa_grouped": True}, {"ffn_act_f32": False},
                 {"attn_score_dtype": "bfloat16"}):
        cfg = dataclasses.replace(base, **over)
        h1 = M.forward(params, cfg, toks)
        err = float(jnp.abs(h1 - h0).max())
        tol = 1e-5 if over.get("gqa_grouped") else 0.15
        assert err < tol, (over, err)


# ---------------------------------------------------------------------------
# rwkv6 chunked-checkpoint backward memory
# ---------------------------------------------------------------------------
def test_rwkv6_chunked_grad_correct():
    from repro.kernels.rwkv6 import ref
    bh, s, dk, dv = 2, 64, 8, 8
    r = jax.random.normal(jax.random.PRNGKey(0), (bh, s, dk)) * 0.5
    k = jax.random.normal(jax.random.PRNGKey(1), (bh, s, dk)) * 0.5
    v = jax.random.normal(jax.random.PRNGKey(2), (bh, s, dv)) * 0.5
    w = jax.nn.sigmoid(jax.random.normal(jax.random.PRNGKey(3),
                                         (bh, s, dk)))
    u = jax.random.normal(jax.random.PRNGKey(4), (bh, dk)) * 0.5

    def loss(chunk):
        return jnp.sum(ref.rwkv6(r, k, v, w, u, chunk=chunk) ** 2)

    g16 = jax.grad(lambda x: jnp.sum(
        ref.rwkv6(x, k, v, w, u, chunk=16) ** 2))(r)
    g64 = jax.grad(lambda x: jnp.sum(
        ref.rwkv6(x, k, v, w, u, chunk=64) ** 2))(r)
    np.testing.assert_allclose(np.asarray(g16), np.asarray(g64),
                               rtol=1e-4, atol=1e-4)
    assert loss(16) == pytest.approx(loss(64), rel=1e-5)


# ---------------------------------------------------------------------------
# regeneration ladder policy
# ---------------------------------------------------------------------------
def test_regeneration_ladder_shapes():
    import importlib
    jax.devices()        # pin the backend BEFORE dryrun sets XLA_FLAGS
    dr = importlib.import_module("repro.launch.dryrun")
    for kind in ("train", "prefill", "decode"):
        ladder = dr.regeneration_ladder(kind)
        assert len(ladder) >= 1
        for label, patch, mb in ladder:
            assert isinstance(label, str) and isinstance(patch, dict)
            assert mb >= 1
    # train rungs escalate microbatches monotonically
    mbs = [mb for _, _, mb in dr.regeneration_ladder("train")]
    assert mbs == sorted(mbs)
