"""Fault-tolerant serving: breakers, chaos injection, graceful degradation.

Every failure here is injected deterministically (``ChaosPlan``, injectable
breaker clocks, fake calibration measurements), so the degradation paths —
fallback-to-jit, quarantine, background re-solve, straggler rotation,
admission rejection — are pinned down bit-for-bit with no real faults and
no timing flakes.
"""
from __future__ import annotations

import json
import os
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.codegen import (allclose, clear_program_cache, random_inputs,
                           reference_executor)
from repro.core import SolverOptions, THREE_SLICE, polybench, solve
from repro.ft import (BackoffPolicy, BreakerState, ChaosPlan, CircuitBreaker,
                      DeadlineExceeded, EngineOverloaded, InjectedFailure,
                      StragglerConfig, atomic_write_json, load_json,
                      payload_checksum, quarantine_file, scrub_cache_dir)
from repro.ft.artifacts import ArtifactError
from repro.serve import PlanEngine, ServeConfig


def _solved(name: str, budget: float = 1.0):
    g = polybench.build(name)
    plan = solve(g, THREE_SLICE, SolverOptions(time_budget_s=budget))
    return g, plan


def _mm_inputs(seed: int = 0):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.normal(size=(8, 6)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(6, 5)).astype(np.float32))
    return a, b


FAST = dict(resolve_backoff_s=0.01, resolve_backoff_mult=1.0,
            resolve_max_retries=4)


def _wait_recovered(eng, name, timeout=30.0):
    assert eng._health_for(name).recovered_event.wait(timeout), \
        f"background re-solve of {name!r} did not finish in {timeout}s"


# ---------------------------------------------------------------------------
# Circuit breaker state machine (injected clock — no real sleeping)
# ---------------------------------------------------------------------------
def test_breaker_open_half_open_close_transitions():
    clock = {"t": 0.0}
    br = CircuitBreaker(threshold=2, reset_s=10.0, clock=lambda: clock["t"])
    assert br.state is BreakerState.CLOSED and br.allow()
    assert not br.record_failure()              # 1/2: still closed
    assert br.record_failure()                  # 2/2: opened now
    assert br.state is BreakerState.OPEN
    assert not br.allow()                       # quarantined
    clock["t"] = 9.9
    assert not br.allow()                       # reset_s not elapsed
    clock["t"] = 10.0
    assert br.allow()                           # half-open: one probe
    assert br.state is BreakerState.HALF_OPEN
    assert not br.allow()                       # second probe refused
    br.record_success()
    assert br.state is BreakerState.CLOSED and br.allow()
    assert br.stats()["transitions"] == ["open", "half_open", "closed"]


def test_breaker_half_open_failure_reopens():
    clock = {"t": 0.0}
    br = CircuitBreaker(threshold=1, reset_s=5.0, clock=lambda: clock["t"])
    assert br.record_failure() and br.state is BreakerState.OPEN
    clock["t"] = 5.0
    assert br.allow() and br.state is BreakerState.HALF_OPEN
    # a failed probe re-opens AND reports it, so recovery is re-triggered
    assert br.record_failure()
    assert br.state is BreakerState.OPEN
    clock["t"] = 9.0                    # reset clock restarted at t=5
    assert not br.allow()
    clock["t"] = 10.0
    assert br.allow()


def test_breaker_force_open_and_thread_safety():
    br = CircuitBreaker(threshold=100, reset_s=1e9)
    br.force_open()
    assert br.state is BreakerState.OPEN and not br.allow()
    hits = []
    br2 = CircuitBreaker(threshold=4, reset_s=1e9)

    def hammer(_):
        if br2.record_failure():
            hits.append(1)

    threads = [threading.Thread(target=hammer, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(hits) == 1               # exactly one thread opened it


def test_backoff_schedule_is_deterministic_and_capped():
    p = BackoffPolicy(base_s=0.1, mult=2.0, max_s=0.5, retries=5)
    assert p.delays() == [0.1, 0.2, 0.4, 0.5, 0.5]
    assert p.delays() == p.delays()     # pure


# ---------------------------------------------------------------------------
# ChaosPlan determinism
# ---------------------------------------------------------------------------
def test_chaos_plan_fires_each_index_once_per_name():
    cp = ChaosPlan(compile_fail_at=(1,), execute_fail_at=(0,))
    cp.on_compile("a")                          # index 0: clean
    with pytest.raises(InjectedFailure):
        cp.on_compile("a")                      # index 1: fires once
    cp.on_compile("a")                          # index 2: clean again
    with pytest.raises(InjectedFailure):
        cp.on_execute("a")
    cp.on_execute("a")
    assert ("compile", "a", 1) in cp.events
    assert ("execute", "a", 0) in cp.events


def test_chaos_plan_only_restricts_entry_and_corrupts_floats():
    cp = ChaosPlan(corrupt_at=(0,), only="victim")
    out = {"x": jnp.ones((2, 2)), "i": jnp.arange(3)}
    same = cp.corrupt_outputs("bystander", out)
    assert same is out                          # wrong name: untouched
    bad = cp.corrupt_outputs("victim", out)
    assert bool(jnp.isnan(bad["x"]).all())
    assert bad["i"].dtype == out["i"].dtype     # ints pass through
    assert cp.corrupt_outputs("victim", out) is out     # fired already


def test_chaos_corrupt_file_modes(tmp_path):
    p = tmp_path / "f.json"
    p.write_text('{"ok": 1}')
    ChaosPlan.corrupt_file(str(p))
    with pytest.raises(Exception):
        json.loads(p.read_text(errors="ignore") or "x")
    p.write_text('{"ok": 1}')
    ChaosPlan.corrupt_file(str(p), mode="truncate")
    assert os.path.getsize(p) == 0


# ---------------------------------------------------------------------------
# Checksummed atomic artifacts
# ---------------------------------------------------------------------------
def test_artifact_checksum_round_trip_and_detection(tmp_path):
    p = str(tmp_path / "a.json")
    atomic_write_json(p, {"x": [1, 2], "y": "z"})
    d = load_json(p, require_checksum=True)
    assert d == {"x": [1, 2], "y": "z"}
    assert payload_checksum(d) == payload_checksum({"y": "z", "x": [1, 2]})
    # flip a byte inside the payload: checksum must catch it
    raw = open(p).read().replace('"z"', '"q"')
    open(p, "w").write(raw)
    with pytest.raises(ArtifactError):
        load_json(p)
    ChaosPlan.corrupt_file(p)               # non-JSON garbage
    with pytest.raises(ArtifactError):
        load_json(p)


def test_quarantine_and_scrub(tmp_path):
    p = tmp_path / "bad.json"
    p.write_text("garbage")
    moved = quarantine_file(str(p), reason="test")
    assert not p.exists() and moved.endswith(".corrupt")
    (tmp_path / "empty.bin").write_bytes(b"")
    (tmp_path / "x.tmp123").write_bytes(b"partial")
    (tmp_path / "keep.bin").write_bytes(b"data")
    removed = scrub_cache_dir(str(tmp_path))
    assert len(removed) == 2
    assert (tmp_path / "keep.bin").exists()


def test_persistent_cache_metadata_survives_corruption(tmp_path):
    import jax

    from repro.codegen import enable_persistent_cache
    from repro.codegen import program as program_mod
    d = str(tmp_path / "aot")
    old_dir = program_mod._persistent_dir
    try:
        enable_persistent_cache(d)
        meta = os.path.join(d, "repro-cache-metadata.json")
        doc = load_json(meta, require_checksum=True)
        assert doc["schema"] == 1
        ChaosPlan.corrupt_file(meta)
        enable_persistent_cache(d)          # quarantine + rewrite, no crash
        assert os.path.exists(meta + ".corrupt")
        assert load_json(meta, require_checksum=True)["schema"] == 1
        # crash leftovers in the cache dir are scrubbed on (re-)enable
        open(os.path.join(d, "entry.tmp.123"), "wb").close()
        enable_persistent_cache(d)
        assert not os.path.exists(os.path.join(d, "entry.tmp.123"))
    finally:
        program_mod._persistent_dir = old_dir
        jax.config.update("jax_compilation_cache_dir", old_dir)


def test_corrupted_calibration_profile_is_regenerated(tmp_path, monkeypatch):
    from repro.calibrate import cached_profile, calibrate, profile_path
    from test_calibrate import FakeBench
    monkeypatch.setenv("REPRO_CALIBRATION_DIR", str(tmp_path))
    calibrate(bench=FakeBench())
    path = profile_path("fake", 1, 2)
    ChaosPlan.corrupt_file(path)
    # quiet path: quarantines, returns None, never raises
    assert cached_profile(path=path) is None
    assert os.path.exists(path + ".corrupt") and not os.path.exists(path)
    # explicit path: re-measures and regenerates a valid profile
    prof = calibrate(bench=FakeBench())
    assert prof.dispatch_s == 5e-5
    assert cached_profile(path=path) is not None


# ---------------------------------------------------------------------------
# Graceful degradation: fallback equals the jax.jit oracle
# ---------------------------------------------------------------------------
def test_compile_failure_falls_back_then_recovers():
    clear_program_cache()
    a, b = _mm_inputs()
    chaos = ChaosPlan(compile_fail_at=(0,))
    eng = PlanEngine(impl="xla", sc=ServeConfig(chaos=chaos, **FAST))
    eng.register_function("mm", lambda x, y: x @ y, (a, b),
                          solver_opts=SolverOptions(time_budget_s=1.0))
    expect = np.asarray(a @ b)
    out = eng.submit("mm", (a, b))          # injected compile failure
    np.testing.assert_allclose(np.asarray(out), expect, rtol=2e-4)
    h = eng.stats()["resilience"]["entries"]["mm"]
    assert h["failures"] == 1 and h["fallbacks"] == 1 and h["ok"] == 0
    # one failure < threshold: breaker still closed, next submit optimized
    assert h["state"] == "ok"
    out2 = eng.submit("mm", (a, b))
    np.testing.assert_allclose(np.asarray(out2), expect, rtol=2e-4)
    h = eng.stats()["resilience"]["entries"]["mm"]
    assert h["ok"] == 1
    assert h["ok"] + h["fallbacks"] == eng.stats()["per_name"]["mm"]


def test_repeated_failures_quarantine_and_background_resolve():
    clear_program_cache()
    a, b = _mm_inputs()
    chaos = ChaosPlan(execute_fail_at=(0, 1), only="mm")
    eng = PlanEngine(impl="xla", sc=ServeConfig(
        chaos=chaos, breaker_threshold=2, breaker_reset_s=1e9, **FAST))
    eng.register_function("mm", lambda x, y: x @ y, (a, b),
                          solver_opts=SolverOptions(time_budget_s=1.0))
    expect = np.asarray(a @ b)
    for _ in range(2):                      # both injected failures
        out = eng.submit("mm", (a, b))
        np.testing.assert_allclose(np.asarray(out), expect, rtol=2e-4)
    h = eng.stats()["resilience"]["entries"]["mm"]
    assert h["state"] == "quarantined" and h["failures"] == 2
    # quarantined: submits keep answering correctly via the fallback
    out = eng.submit("mm", (a, b))
    np.testing.assert_allclose(np.asarray(out), expect, rtol=2e-4)
    _wait_recovered(eng, "mm")
    h = eng.stats()["resilience"]["entries"]["mm"]
    assert h["state"] == "ok" and h["recovered"] == 1
    assert h["resolve_attempts"] >= 1
    out = eng.submit("mm", (a, b))          # optimized path again
    np.testing.assert_allclose(np.asarray(out), expect, rtol=2e-4)
    assert eng.stats()["resilience"]["entries"]["mm"]["ok"] >= 1
    eng.shutdown()


def test_canary_catches_miscompile_and_quarantines_immediately():
    """Corrupted kernel output (NaN injected post-execution) never reaches
    the caller: the canary catches it, the entry quarantines in ONE
    failure (miscompiles are never transient), the request is re-served
    by the oracle path."""
    clear_program_cache()
    g, plan = _solved("2-madd")
    ins = random_inputs(g, seed=0)
    ref = reference_executor(g)(ins)
    chaos = ChaosPlan(corrupt_at=(0,))
    eng = PlanEngine(impl="xla", sc=ServeConfig(
        chaos=chaos, canary_every=1, breaker_reset_s=1e9, **FAST))
    eng.register("m", g, plan)
    out = eng.submit("m", ins)
    assert all(allclose(out[k], ref[k]) for k in ref)   # correct anyway
    h = eng.stats()["resilience"]["entries"]["m"]
    assert h["state"] == "quarantined"
    assert h["canaries"] == 1 and h["failures"] == 1
    assert "MiscompileError" in h["last_error"]
    _wait_recovered(eng, "m")
    out = eng.submit("m", ins)
    assert all(allclose(out[k], ref[k]) for k in ref)
    h = eng.stats()["resilience"]["entries"]["m"]
    assert h["state"] == "ok" and h["ok"] == 1
    eng.shutdown()


def test_canary_validates_function_entries_against_jit_oracle():
    clear_program_cache()
    a, b = _mm_inputs()
    eng = PlanEngine(impl="xla", sc=ServeConfig(canary_every=1))
    eng.register_function("mm", lambda x, y: x @ y, (a, b),
                          solver_opts=SolverOptions(time_budget_s=1.0))
    for _ in range(3):
        out = eng.submit("mm", (a, b))
        np.testing.assert_allclose(np.asarray(out), np.asarray(a @ b),
                                   rtol=2e-4)
    h = eng.stats()["resilience"]["entries"]["mm"]
    assert h["canaries"] == 3 and h["canary_failures"] == 0
    assert h["state"] == "ok" and h["ok"] == 3


def test_registration_failure_degrades_to_plain_jit():
    """A function the frontend cannot serve (lowers to an empty graph)
    still registers: every submit is answered by jax.jit, stats() shows
    the entry as fallback, and re-solve attempts are bounded."""
    clear_program_cache()
    x = jnp.arange(6, dtype=jnp.float32)
    eng = PlanEngine(impl="xla", sc=ServeConfig(**FAST))
    tf = eng.register_function("ident", lambda v: v, (x,))
    assert tf is None                       # degraded registration
    out = eng.submit("ident", (x,))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))
    h = eng.stats()["resilience"]["entries"]["ident"]
    assert h["state"] == "fallback" and h["fallbacks"] == 1
    # without fallback the same registration raises instead
    eng2 = PlanEngine(impl="xla", sc=ServeConfig(fallback=False))
    with pytest.raises(ValueError):
        eng2.register_function("ident", lambda v: v, (x,))
    eng.shutdown()


def test_failed_submit_does_not_corrupt_accounting():
    """The first satellite fix: a failure mid-submit must leave request
    counters, per-name counts and pool cursors conservation-clean."""
    clear_program_cache()
    g, plan = _solved("2-madd")
    ins = random_inputs(g, seed=0)
    chaos = ChaosPlan(execute_fail_at=(1, 3))
    eng = PlanEngine(impl="xla", sc=ServeConfig(
        pool_size=2, chaos=chaos, breaker_threshold=10))
    eng.register("m", g, plan)
    warm = eng.stats()["requests"]
    for _ in range(6):
        eng.submit("m", ins)
    s = eng.stats()
    assert s["requests"] == warm + 6
    h = s["resilience"]["entries"]["m"]
    assert h["failures"] == 2 and h["fallbacks"] == 2
    assert h["ok"] + h["fallbacks"] == s["per_name"]["m"]
    # pool cursor advanced exactly once per *completed* optimized
    # execution — injected execute failures fire before dispatch
    pool = s["pools"]["m/xla"]
    assert pool["calls"] == warm + h["ok"]


def test_user_errors_raise_and_are_not_counted():
    clear_program_cache()
    a, b = _mm_inputs()
    eng = PlanEngine(impl="xla")
    eng.register_function("mm", lambda x, y: x @ y, (a, b),
                          solver_opts=SolverOptions(time_budget_s=1.0))
    before = eng.stats()["per_name"].get("mm", 0)
    with pytest.raises(KeyError):
        eng.submit("nope", (a, b))          # unknown entry: caller bug
    with pytest.raises((TypeError, ValueError)):
        eng.submit("mm", (a,))              # wrong arity: caller bug
    s = eng.stats()
    # neither request was counted
    assert s["per_name"].get("mm", 0) == before
    assert s["resilience"]["entries"]["mm"]["failures"] == 0


# ---------------------------------------------------------------------------
# Admission control + deadlines
# ---------------------------------------------------------------------------
def test_admission_rejects_when_inflight_depth_full():
    clear_program_cache()
    g, plan = _solved("2-madd")
    ins = random_inputs(g, seed=0)
    eng = PlanEngine(impl="xla", sc=ServeConfig(
        max_inflight=1, admission_timeout_s=0.02))
    eng.register("m", g, plan)
    eng.warmup("m", ins)
    assert eng._inflight_sem.acquire(timeout=1.0)   # occupy the only slot
    try:
        with pytest.raises(EngineOverloaded):
            eng.submit("m", ins)
        with pytest.raises(DeadlineExceeded):
            eng.submit("m", ins, deadline_s=0.005)
    finally:
        eng._inflight_sem.release()
    out = eng.submit("m", ins)              # slot free: served normally
    ref = reference_executor(g)(ins)
    assert all(allclose(out[k], ref[k]) for k in ref)
    r = eng.stats()["resilience"]
    assert r["rejected"] == 1 and r["deadline_rejected"] == 1


def test_deadline_miss_is_counted_not_fatal():
    clear_program_cache()
    g, plan = _solved("2-madd")
    ins = random_inputs(g, seed=0)
    eng = PlanEngine(impl="xla", sc=ServeConfig(deadline_s=1e-9))
    eng.register("m", g, plan)
    out = eng.submit("m", ins)              # admitted; finishes late
    ref = reference_executor(g)(ins)
    assert all(allclose(out[k], ref[k]) for k in ref)
    assert eng.stats()["resilience"]["deadline_misses"] >= 1


# ---------------------------------------------------------------------------
# Straggler rotation: a persistently slow pool clone leaves round-robin
# ---------------------------------------------------------------------------
def test_slow_clone_is_rotated_out_of_round_robin():
    clear_program_cache()
    g, plan = _solved("2-madd")
    ins = random_inputs(g, seed=0)
    chaos = ChaosPlan(slow_clone=1, slow_s=0.05)
    eng = PlanEngine(impl="xla", sc=ServeConfig(
        pool_size=2, chaos=chaos,
        straggler=StragglerConfig(threshold=1.5, patience=2, min_steps=1,
                                  ema=0.5)))
    eng.register("m", g, plan)
    eng.warmup("m", ins)
    for _ in range(8):
        eng.submit("m", ins)
    s = eng.stats()
    assert s["pools"]["m/xla"]["disabled_clones"] == [1]
    assert s["resilience"]["entries"]["m"]["rotated_clones"] == [1]
    # post-rotation submits all land on the healthy clone and stay correct
    ref = reference_executor(g)(ins)
    out = eng.submit("m", ins)
    assert all(allclose(out[k], ref[k]) for k in ref)


# ---------------------------------------------------------------------------
# The acceptance scenario: three faults in one run, zero wrong answers
# ---------------------------------------------------------------------------
def test_chaos_run_compile_fail_miscompile_corrupt_calibration(
        tmp_path, monkeypatch):
    from repro.calibrate import cached_profile, calibrate, profile_path
    from test_calibrate import FakeBench
    monkeypatch.setenv("REPRO_CALIBRATION_DIR", str(tmp_path))
    calibrate(bench=FakeBench())
    cal_path = profile_path("fake", 1, 2)
    ChaosPlan.corrupt_file(cal_path)        # fault 1: torn calibration
    # the quiet profile-load path hits the torn file first: it must be
    # quarantined and reported absent, never crash the consumer
    assert cached_profile(path=cal_path) is None
    assert os.path.exists(cal_path + ".corrupt")
    regenerated = calibrate(bench=FakeBench())      # cold path regenerates
    assert regenerated.dispatch_s == 5e-5

    clear_program_cache()
    a, b = _mm_inputs()
    g, plan = _solved("2-madd")
    ins = random_inputs(g, seed=0)
    ref = reference_executor(g)(ins)
    expect_mm = np.asarray(a @ b)
    chaos = ChaosPlan(compile_fail_at=(0,),   # fault 2: compile failure
                      corrupt_at=(0,))        # fault 3: miscompile
    eng = PlanEngine(impl="xla", sc=ServeConfig(
        chaos=chaos, canary_every=1, breaker_threshold=1,
        breaker_reset_s=1e9, **FAST))
    # corrupted profile must not crash registration's solve path
    eng.register_function("mm", lambda x, y: x @ y, (a, b),
                          solver_opts=SolverOptions(time_budget_s=1.0))
    eng.register("m", g, plan)

    for i in range(4):                      # every submit answers correctly
        out = eng.submit("mm", (a, b))
        np.testing.assert_allclose(np.asarray(out), expect_mm, rtol=2e-4)
        out = eng.submit("m", ins)
        assert all(allclose(out[k], ref[k]) for k in ref)

    s = eng.stats()["resilience"]["entries"]
    assert s["mm"]["failures"] >= 1         # compile fault fired + fell back
    assert s["m"]["canary_failures"] >= 0 and s["m"]["failures"] >= 1
    assert {("compile", "mm", 0), ("corrupt", "m", 0)} <= set(chaos.events)
    # the miscompiled entry quarantined, then the breaker closed again
    # after background re-solve validated a rebuilt program
    _wait_recovered(eng, "m")
    assert eng.stats()["resilience"]["entries"]["m"]["state"] == "ok"
    out = eng.submit("m", ins)
    assert all(allclose(out[k], ref[k]) for k in ref)
    # conservation: every admitted request landed in exactly one bucket
    s = eng.stats()
    for name in ("mm", "m"):
        h = s["resilience"]["entries"][name]
        assert h["ok"] + h["fallbacks"] == s["per_name"][name]
    eng.shutdown()
