"""Optional-hypothesis shim: property tests degrade to a deterministic grid.

``hypothesis`` is an optional extra; without it the property-based tests in
test_costmodel.py / test_padding.py used to crash collection of the whole
suite.  This shim provides drop-in ``given`` / ``settings`` / ``st`` that
parametrize over a small deterministic sample of each strategy's domain, so
tier-1 stays green (with reduced — but nonzero — property coverage) when the
dependency is absent.
"""
from __future__ import annotations

__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import itertools

    import pytest

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, samples):
            self.samples = list(samples)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            span = max_value - min_value
            vals = {min_value, max_value,
                    min_value + span // 2,
                    min_value + span // 3,
                    min_value + (2 * span) // 3,
                    min_value + span // 7}
            return _Strategy(sorted(vals))

        @staticmethod
        def sampled_from(elements):
            return _Strategy(elements)

    st = _Strategies()

    def settings(**_kw):
        return lambda f: f

    def given(**strategies):
        names = list(strategies)
        pools = [strategies[n].samples for n in names]
        combos = list(itertools.product(*pools))
        if len(combos) > 36:                     # deterministic thinning
            step = max(len(combos) // 36, 1)
            combos = combos[::step][:36]
        if len(names) == 1:
            combos = [c[0] for c in combos]

        def deco(f):
            return pytest.mark.parametrize(",".join(names), combos)(f)

        return deco
