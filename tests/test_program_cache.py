"""Serving-layer program cache: LRU eviction, executable pool, segments,
persistent AOT warm start, and the solver/serve shared-executable contract.
"""
from __future__ import annotations

import glob
import os
import types

import jax
import pytest

from repro.codegen import (allclose, cache_stats, clear_program_cache,
                           compiled_program, plan_executor, program_cache,
                           program_key, random_inputs, reference_executor)
from repro.codegen.program import ProgramCache
from repro.core import SolverOptions, THREE_SLICE, polybench, solve


def _solved(name: str, budget: float = 2.0):
    g = polybench.build(name)
    plan = solve(g, THREE_SLICE, SolverOptions(time_budget_s=budget))
    return g, plan


def _fake_program(n: int):
    return types.SimpleNamespace(est_bytes=lambda: n, pool_size=1,
                                 n_segments=1, calls=0)


# ---------------------------------------------------------------------------
# LRU mechanics (pure, no compilation)
# ---------------------------------------------------------------------------
def test_lru_eviction_order():
    cache = ProgramCache(capacity=2)
    for i, key in enumerate(("a", "b", "c")):
        cache.put((key,), _fake_program(i))
    # capacity 2: "a" (the LRU) was evicted, "b"/"c" stay
    assert cache.keys() == [("b",), ("c",)]
    assert cache.evictions == 1
    # touching "b" makes it MRU, so inserting "d" now evicts "c"
    assert cache.get(("b",)) is not None
    cache.put(("d",), _fake_program(3))
    assert cache.keys() == [("b",), ("d",)]
    assert cache.evictions == 2
    assert cache.get(("c",)) is None


def test_lru_resize_evicts_overflow():
    cache = ProgramCache(capacity=4)
    for key in "abcd":
        cache.put((key,), _fake_program(1))
    cache.resize(2)
    assert cache.keys() == [("c",), ("d",)]
    assert cache.evictions == 2


def test_cache_stats_has_one_source_of_truth():
    cache = ProgramCache(capacity=2)
    cache.put(("a",), _fake_program(100))
    cache.get(("a",))
    cache.get(("a",))
    s = cache.stats(detail=True)
    assert s["size"] == 1 and s["capacity"] == 2
    assert s["hits"] == 2 and s["evictions"] == 0
    assert s["est_bytes"] == 100
    (entry,) = s["entries"].values()
    assert entry["hits"] == 2 and entry["est_bytes"] == 100
    # the global surface exposes the same keys the bench gate reads
    for k in ("size", "capacity", "hits", "misses", "evictions",
              "est_bytes"):
        assert k in cache_stats()


def test_global_cache_eviction_integration():
    from repro.codegen import set_program_cache_size
    clear_program_cache()
    old_capacity = program_cache().capacity
    try:
        set_program_cache_size(1)
        g1, p1 = _solved("2-madd", budget=1.0)
        g2, p2 = _solved("3-madd", budget=1.0)
        prog1 = compiled_program(g1, p1, "xla")
        key1 = program_key(g1, p1, "xla")
        assert key1 in program_cache()
        compiled_program(g2, p2, "xla")     # evicts the 2-madd entry
        assert key1 not in program_cache()
        assert cache_stats()["evictions"] == 1
        # the evicted program still executes (callers holding a reference
        # are unaffected); re-requesting it is a rebuild, not an error
        ins = random_inputs(g1, seed=0)
        out = prog1(ins)
        rebuilt = compiled_program(g1, p1, "xla")
        assert rebuilt is not prog1
        assert cache_stats()["misses"] == 3
        ref = reference_executor(g1)(ins)
        assert all(allclose(out[k], ref[k]) for k in ref)
    finally:
        set_program_cache_size(old_capacity)
        clear_program_cache()


# ---------------------------------------------------------------------------
# Executable pool
# ---------------------------------------------------------------------------
def test_pool_round_robin_identity():
    clear_program_cache()
    g, plan = _solved("2-madd", budget=1.0)
    prog = compiled_program(g, plan, "xla", pool_size=3)
    assert prog.pool_size == 3
    # three distinct clone sets, each with its own jitted executables
    assert len(prog._pool) == 3
    flat = [fn for fns in prog._pool for fn in fns]
    assert len(set(map(id, flat))) == len(flat)
    ins = random_inputs(g, seed=0)
    ref = reference_executor(g)(ins)
    outs = [prog(ins) for _ in range(4)]
    # calls cycle the pool: 3 clones traced after 3 calls, none after
    assert prog.calls == 4
    assert prog.trace_count == 3 * prog.n_segments
    for out in outs:
        assert all(allclose(out[k], ref[k]) for k in ref)


def test_pool_size_change_rebuilds_entry():
    clear_program_cache()
    g, plan = _solved("2-madd", budget=1.0)
    p1 = compiled_program(g, plan, "xla")            # default pool (1)
    p2 = compiled_program(g, plan, "xla", pool_size=2)
    assert p1 is not p2 and p2.pool_size == 2
    # an unspecified pool_size reuses whatever is cached
    assert compiled_program(g, plan, "xla") is p2


# ---------------------------------------------------------------------------
# Materialization segments (the gemver producer-cloning fix)
# ---------------------------------------------------------------------------
def test_gemver_segments_at_multi_consumer_boundary():
    clear_program_cache()
    g, plan = _solved("gemver", budget=2.0)
    prog = compiled_program(g, plan, "xla")
    # Ah feeds both the x-update and the w-update: it must terminate a
    # segment so XLA cannot clone the rank-2 update into each consumer
    assert prog.n_segments == 2
    first = prog.segments[0]
    assert prog.lowered[first.tids[-1]].out_array in first.out_arrays
    ins = random_inputs(g, seed=1)
    ref = reference_executor(g)(ins)
    out = prog(ins)
    assert all(allclose(out[k], ref[k]) for k in ref)


def test_single_consumer_graphs_stay_one_segment():
    clear_program_cache()
    for name in ("2mm", "2-madd"):
        g, plan = _solved(name, budget=1.0)
        prog = compiled_program(g, plan, "xla")
        assert prog.n_segments == 1, name


# ---------------------------------------------------------------------------
# Persistent AOT compilation cache (cross-process warm start)
# ---------------------------------------------------------------------------
def test_persistent_cache_warm_start(tmp_path):
    try:
        import jax._src.compilation_cache as cc
    except ImportError:
        pytest.skip("jax compilation-cache internals unavailable")
    from repro.codegen import enable_persistent_cache
    from repro.codegen import program as program_mod

    cache_dir = str(tmp_path / "aot")
    os.makedirs(cache_dir, exist_ok=True)
    g, plan = _solved("2-madd", budget=1.0)
    ins = random_inputs(g, seed=0)
    old_dir = program_mod._persistent_dir
    try:
        enable_persistent_cache(cache_dir)
        clear_program_cache()
        exe = plan_executor(g, plan, impl="xla")
        jax.block_until_ready(list(exe(ins).values()))
        # the engine's own checksummed metadata file is not an XLA artifact
        n_artifacts = len([p for p in glob.glob(
            os.path.join(cache_dir, "*"))
            if "repro-cache-metadata" not in p])
        if n_artifacts == 0:
            pytest.skip("backend does not persist executables")

        # simulate a fresh replica: drop the program cache AND jax's
        # in-memory jit caches, keep only the on-disk artifacts
        clear_program_cache()
        jax.clear_caches()
        hits = {"n": 0}
        orig = cc.get_executable_and_time

        def spy(*args, **kw):
            result = orig(*args, **kw)
            if result[0] is not None:
                hits["n"] += 1
            return result

        cc.get_executable_and_time = spy
        try:
            exe2 = plan_executor(g, plan, impl="xla")
            out = exe2(ins)
            jax.block_until_ready(list(out.values()))
        finally:
            cc.get_executable_and_time = orig
        # the second build compiled nothing new: every lowering came back
        # from the persistent cache, and no new artifact was written
        assert hits["n"] >= 1
        assert len([p for p in glob.glob(os.path.join(cache_dir, "*"))
                    if "repro-cache-metadata" not in p]) == n_artifacts
        ref = reference_executor(g)(ins)
        assert all(allclose(out[k], ref[k]) for k in ref)
    finally:
        program_mod._persistent_dir = old_dir
        jax.config.update("jax_compilation_cache_dir", old_dir)
        try:
            # unlatch the file-cache backend: the tmpdir dies with the test
            cc.reset_cache()
        except Exception:
            pass
        clear_program_cache()


# ---------------------------------------------------------------------------
# Solver measurement and serving share executables
# ---------------------------------------------------------------------------
def test_measure_plan_and_engine_share_executables():
    from repro.core import measure_plan
    from repro.serve import PlanEngine, ServeConfig

    clear_program_cache()
    g, plan = _solved("2-madd", budget=1.0)
    seconds, gflops, ok = measure_plan("2-madd", plan, graph=g, repeats=1,
                                       impl="xla")
    assert ok and seconds > 0
    key = program_key(g, plan, "xla")
    assert key in program_cache()
    misses_after_measure = cache_stats()["misses"]

    eng = PlanEngine(impl="xla", sc=ServeConfig())
    eng.register("m", g, plan)
    ins = random_inputs(g, seed=0)
    out = eng.submit("m", ins)
    # serving resolved the SAME executable measurement built: no new miss
    assert cache_stats()["misses"] == misses_after_measure
    assert cache_stats()["hits"] >= 1
    ref = reference_executor(g)(ins)
    assert all(allclose(out[k], ref[k]) for k in ref)
