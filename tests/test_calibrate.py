"""Calibration: profile round-trip, cache semantics, cost-model consumption.

All tests inject deterministic fake measurements (``FakeBench``) — CI never
times real hardware, so results are stable on any runner.
"""
from __future__ import annotations

import json
import os

import pytest

from repro.calibrate import (CONTRACTION_SIZES, CalibratedHardware,
                             cached_profile, calibrate, calibration_dir,
                             profile_path)
from repro.core import SolverOptions, THREE_SLICE, polybench, solve
from repro.core.costmodel import plan_latency, topo_waves
from repro.core.fusion import fuse
from repro.core.resources import Hardware


class FakeBench:
    """Deterministic measurement injection with the Microbench surface.

    Defaults mimic a small CPU host: dispatch is tens of microseconds,
    compute tens of GFLOP/s, streams cheap relative to compute — the
    regime where spreading independent tasks across slices pays.
    """

    def __init__(self, dispatch_s=5e-5, ici_bw=8e9, hbm_bw=12e9,
                 share=(1.0, 0.7, 0.55), gflops=(20.0, 40.0, 60.0)):
        self.dispatch_s = dispatch_s
        self.ici_bw = ici_bw
        self.hbm_bw = hbm_bw
        self.share = share
        self.gflops = dict(zip(sorted(CONTRACTION_SIZES.values()), gflops))
        self.calls = 0

    def identity(self):
        return ("fake", 1, 2)

    def measure_dispatch_s(self):
        self.calls += 1
        return self.dispatch_s

    def measure_ici_bw(self):
        self.calls += 1
        return self.ici_bw

    def measure_hbm_bw(self, n_concurrent=1):
        self.calls += 1
        return self.hbm_bw * self.share[n_concurrent - 1]

    def measure_gflops(self, n):
        self.calls += 1
        return self.gflops[n]


@pytest.fixture()
def cal_dir(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_CALIBRATION_DIR", str(tmp_path))
    return str(tmp_path)


# ---------------------------------------------------------------------------
# Profile round-trip + cache
# ---------------------------------------------------------------------------
def test_profile_round_trip(cal_dir):
    prof = calibrate(bench=FakeBench())
    path = profile_path("fake", 1, 2)
    assert path.startswith(cal_dir) and os.path.exists(path)
    assert CalibratedHardware.load(path) == prof
    assert prof.dispatch_s == 5e-5
    assert prof.hbm_share == (1.0, 0.7, 0.55)
    assert prof.peak_flops == 60.0 * 1e9
    assert set(prof.gflops) == set(CONTRACTION_SIZES)


def test_calibrate_serves_from_cache_without_measuring(cal_dir):
    first = FakeBench()
    prof = calibrate(bench=first)
    assert first.calls > 0
    again = FakeBench(dispatch_s=999.0)      # would change the profile...
    prof2 = calibrate(bench=again)
    assert again.calls == 0                  # ...but was never measured
    assert prof2 == prof
    forced = calibrate(bench=again, force=True)
    assert again.calls > 0 and forced.dispatch_s == 999.0


def test_corrupt_or_stale_cache_remeasures(cal_dir):
    path = profile_path("fake", 1, 2)
    os.makedirs(cal_dir, exist_ok=True)
    with open(path, "w") as f:
        f.write("{not json")
    prof = calibrate(bench=FakeBench())
    assert prof.dispatch_s == 5e-5           # re-measured, cache replaced
    with open(path, "w") as f:
        json.dump({"schema": -1}, f)
    assert calibrate(bench=FakeBench()).schema == 1


def test_quick_profile_does_not_satisfy_full_calibration(cal_dir):
    """A cached smoke-quality (quick) profile must not silently serve
    full-fidelity requests — a full calibrate() re-measures and replaces
    it, while quick requests accept either fidelity."""
    calibrate(bench=FakeBench(), quick=True)
    cached_quick = FakeBench()
    assert calibrate(bench=cached_quick, quick=True).quick
    assert cached_quick.calls == 0               # quick serves from cache
    full = FakeBench(dispatch_s=7e-5)
    prof = calibrate(bench=full)                 # full request: re-measure
    assert full.calls > 0 and not prof.quick
    assert prof.dispatch_s == 7e-5
    # the full profile now serves both fidelities from cache
    quick_again = FakeBench()
    assert calibrate(bench=quick_again, quick=True) == prof
    assert quick_again.calls == 0


def test_cached_profile_never_measures(cal_dir):
    assert cached_profile(path=profile_path("fake", 1, 2)) is None
    calibrate(bench=FakeBench())
    prof = cached_profile(path=profile_path("fake", 1, 2))
    assert prof is not None and prof.ici_bw == 8e9


def test_calibration_dir_env_override(cal_dir):
    assert calibration_dir() == cal_dir


# ---------------------------------------------------------------------------
# Hardware construction + cost-model consumption
# ---------------------------------------------------------------------------
def test_hardware_carries_measured_rates(cal_dir):
    prof = calibrate(bench=FakeBench())
    hw = prof.hardware(n_slices=3)
    assert isinstance(hw, Hardware) and hw.n_slices == 3
    assert hw.peak_flops == prof.peak_flops
    assert hw.ici_bw == prof.ici_bw
    assert hw.dispatch_s == prof.dispatch_s
    # per-slice rates divide the measured board rates
    assert hw.slices[0].flops == pytest.approx(prof.peak_flops / 3)
    assert hw.slices[0].hbm_bw == pytest.approx(prof.hbm_bw)
    # measured share curve replaces the analytic 1/k, clamped past its end
    assert [hw.bw_share_at(k) for k in (1, 2, 3, 4)] == \
        [1.0, 0.7, 0.55, 0.55]
    assert THREE_SLICE.bw_share_at(2) == pytest.approx(0.5)


def test_solver_consumes_calibrated_hardware(cal_dir):
    hw = calibrate(bench=FakeBench()).hardware(n_slices=3)
    g = polybench.build("2-madd")
    plan = solve(g, hw, SolverOptions(time_budget_s=2.0))
    assert plan.latency_s > 0 and plan.configs


def test_solve_default_hardware_uses_cached_profile(cal_dir, monkeypatch):
    """``solve(graph, None)`` picks up this host's cached profile."""
    import repro.calibrate as cal
    g = polybench.build("2-madd")
    # uncalibrated host: quiet fallback to the static board
    monkeypatch.setattr(cal, "cached_profile", lambda path=None: None)
    plan = solve(g, None, SolverOptions(time_budget_s=1.0))
    assert plan.latency_s > 0
    # calibrated host: measured dispatch overhead shows up in the makespan
    prof = calibrate(bench=FakeBench(dispatch_s=1.0))   # absurdly slow host
    monkeypatch.setattr(cal, "cached_profile", lambda path=None: prof)
    plan_cal = solve(g, None, SolverOptions(time_budget_s=1.0))
    assert plan_cal.latency_s >= 1.0        # >= one measured dispatch


# ---------------------------------------------------------------------------
# The acceptance story: measured rates flip the 3mm slice decision
# ---------------------------------------------------------------------------
def test_3mm_splits_independent_matmuls_under_measured_rates(cal_dir):
    """On a host where compute is slow relative to streams and dispatch is
    expensive (every CPU container), the dispatch+serialization saving of
    spreading 3mm's two independent wave-0 matmuls exceeds the stream
    cost, so the calibrated solve must use distinct slices — while the
    static TPU constants (streams dear, compute nearly free) keep the
    single-slice assignment.  This is the ROADMAP "solver under-uses
    concurrency at scale 1" bug, pinned by deterministic fake rates."""
    hw = calibrate(bench=FakeBench()).hardware(n_slices=3)
    g = polybench.build("3mm")
    fg = fuse(g)
    wave_of = topo_waves(fg)
    wave0 = sorted(t for t, w in wave_of.items() if w == 0)
    assert len(wave0) == 2                   # the two independent matmuls

    plan_cal = solve(g, hw, SolverOptions(time_budget_s=12.0))
    cal_slices = {t: plan_cal.configs[t].slice_id for t in wave0}
    assert len(set(cal_slices.values())) == 2, cal_slices

    plan_static = solve(g, THREE_SLICE, SolverOptions(time_budget_s=12.0))
    static_slices = {plan_static.configs[t].slice_id for t in wave0}
    assert len(static_slices) == 1, "static constants should co-locate"


# ---------------------------------------------------------------------------
# Cost-model mechanics the calibration feeds
# ---------------------------------------------------------------------------
def _uniform_configs(fg, slice_of):
    from repro.core.padding import TileOption
    from repro.core.plan import ArrayPlacement, TaskConfig
    cfgs = {}
    for t in fg.tasks:
        tiles = {l: TileOption(10, t.trip_counts[l], t.trip_counts[l])
                 for l in t.loops}
        placements = {a: ArrayPlacement(1, 1)
                      for a in t.read_arrays() + [t.output_array]}
        cfgs[t.tid] = TaskConfig(perm=tuple(t.loops), tiles=tiles,
                                 placements=placements,
                                 slice_id=slice_of(t.tid))
    return cfgs


def test_bw_share_counts_wave_concurrency_not_plan_slices():
    """A sequential 2-task chain on two different slices has ONE active
    slice per wave: each task keeps full HBM bandwidth.  (The old model
    divided by the whole-plan slice count and overcharged every
    multi-wave plan.)"""
    from repro.core.costmodel import task_report
    fg = fuse(polybench.build("2mm"))        # FT0 -> FT1, no parallelism
    cfgs = _uniform_configs(fg, lambda tid: tid)   # slices 0 and 1
    lat, reports = plan_latency(fg, cfgs, THREE_SLICE)
    for t in fg.tasks:
        solo = task_report(t, cfgs[t.tid], fg, THREE_SLICE, bw_share=1.0)
        assert reports[t.tid].latency_s == pytest.approx(solo.latency_s)
    # a genuinely concurrent wave IS de-rated: 3mm's wave 0 on 2 slices
    fg3 = fuse(polybench.build("3mm"))
    cfgs3 = _uniform_configs(fg3, lambda tid: min(tid, 1))
    _, reports3 = plan_latency(fg3, cfgs3, THREE_SLICE)
    halved = task_report(fg3.tasks[0], cfgs3[0], fg3, THREE_SLICE,
                         bw_share=0.5)
    assert reports3[0].latency_s == pytest.approx(halved.latency_s)


def test_dispatch_overhead_serializes_on_shared_slice():
    """dispatch_s charges once per task; co-located tasks pay it
    back-to-back while spread tasks overlap it."""
    fg = fuse(polybench.build("3mm"))
    hw0 = Hardware.make(n_slices=3)
    hw_d = Hardware.make(n_slices=3, dispatch_s=1e-3)
    cfgs_same = _uniform_configs(fg, lambda tid: 0)
    cfgs_split = _uniform_configs(fg, lambda tid: min(tid, 1))
    lat_same0, _ = plan_latency(fg, cfgs_same, hw0)
    lat_same, _ = plan_latency(fg, cfgs_same, hw_d)
    # 3 tasks on one slice: three serialized dispatches
    assert lat_same == pytest.approx(lat_same0 + 3e-3, rel=1e-6)
    lat_split0, _ = plan_latency(fg, cfgs_split, hw0)
    lat_split, _ = plan_latency(fg, cfgs_split, hw_d)
    # wave 0 overlaps its two dispatches: critical path pays only two
    assert lat_split - lat_split0 < 3e-3 - 1e-4
