"""Per-architecture smoke tests + model-level equivalences.

Assignment deliverable (f): every assigned arch instantiates a REDUCED
config of the same family and runs forward/train steps on CPU asserting
output shapes + no NaNs.  Plus: attention implementation equivalence and
prefill/decode consistency (the serving path computes the same function as
the parallel path).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.configs.base import smoke
from repro.models import attention as attn_mod
from repro.models import model as M
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.train_step import train_step

ARCHS = list_archs()


def _inputs(cfg, b=2, s=24, seed=0):
    key = jax.random.PRNGKey(seed)
    if cfg.embed_input:
        toks = jax.random.randint(key, (b, s), 0, cfg.vocab)
    else:
        toks = jax.random.normal(key, (b, s, cfg.d_model))
    labels = jax.random.randint(jax.random.PRNGKey(seed + 1), (b, s), 0,
                                cfg.vocab)
    return toks, labels


def test_all_ten_archs_registered():
    assert len(ARCHS) == 10
    expected = {"recurrentgemma-9b", "qwen3-moe-235b-a22b", "mixtral-8x7b",
                "musicgen-medium", "qwen1.5-0.5b", "yi-34b", "qwen1.5-32b",
                "qwen3-0.6b", "rwkv6-1.6b", "internvl2-76b"}
    assert set(ARCHS) == expected


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_forward(arch):
    cfg = smoke(get_config(arch))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    toks, _ = _inputs(cfg)
    h = M.forward(params, cfg, toks)
    assert h.shape == (2, 24, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(h.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_train_step(arch):
    cfg = smoke(get_config(arch))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    toks, labels = _inputs(cfg)
    new_p, new_o, metrics = train_step(
        params, opt, toks, labels, cfg=cfg,
        opt_cfg=AdamWConfig(warmup_steps=1, total_steps=10))
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["loss"]) > 0
    assert int(new_o.step) == 1
    # parameters actually moved
    delta = sum(float(jnp.sum(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(new_p),
                                jax.tree.leaves(params)))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_prefill_decode_consistency(arch):
    """prefill(t[:s]) then decode(t[s]) must equal prefill(t[:s+1]) logits."""
    cfg = smoke(get_config(arch))
    # fp32 end-to-end; capacity=inf so MoE token drops (which legitimately
    # depend on batch composition) don't mask the equivalence being tested
    cfg = dataclasses.replace(cfg, compute_dtype="float32",
                              kv_cache_dtype="float32",
                              capacity_factor=float("inf"))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    b, s = 2, 12
    toks, _ = _inputs(cfg, b=b, s=s + 1, seed=7)
    logits_full, _ = M.prefill(params, cfg, toks, max_len=32)
    logits_pre, cache = M.prefill(params, cfg, toks[:, :s], max_len=32)
    logits_dec, _ = M.decode_step(params, cfg, cache, toks[:, s])
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_full),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_full_config_exact_dimensions(arch):
    """The registered config matches the published architecture table."""
    cfg = get_config(arch)
    published = {
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
        "qwen1.5-0.5b": (24, 1024, 16, 16, 2816, 151936),
        "yi-34b": (60, 7168, 56, 8, 20480, 64000),
        "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064),
        "qwen3-0.6b": (28, 1024, 16, 8, 3072, 151936),
        "rwkv6-1.6b": (24, 2048, 32, 32, 7168, 65536),
        "internvl2-76b": (80, 8192, 64, 8, 28672, 128256),
    }[arch]
    L, d, h, kv, ff, v = published
    assert cfg.n_layers == L and cfg.d_model == d and cfg.vocab == v
    assert cfg.d_ff == ff
    if arch != "rwkv6-1.6b":       # attn-free arch: heads are wkv heads
        assert (cfg.n_heads, cfg.n_kv_heads) == (h, kv)


def test_moe_and_window_flags():
    moe = get_config("qwen3-moe-235b-a22b")
    assert moe.n_experts == 128 and moe.moe_top_k == 8
    mix = get_config("mixtral-8x7b")
    assert mix.n_experts == 8 and mix.moe_top_k == 2
    assert mix.window is not None                 # SWA
    qw = get_config("qwen1.5-0.5b")
    assert qw.qkv_bias
    q3 = get_config("qwen3-0.6b")
    assert q3.qk_norm
    rg = get_config("recurrentgemma-9b")
    assert rg.pattern == ("rglru", "rglru", "swa")   # local attn is windowed
    assert rg.window is not None
    assert not get_config("musicgen-medium").embed_input   # stub frontend
    assert not get_config("internvl2-76b").embed_input


# ---------------------------------------------------------------------------
# attention implementation equivalence (the solver's choice axis)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("impl", ["chunked", "recursive", "pallas"])
def test_attention_impls_match_naive(impl):
    b, s, h, hkv, d = 2, 192, 4, 2, 16
    q = jax.random.normal(jax.random.PRNGKey(1), (b, s, h, d))
    k = jax.random.normal(jax.random.PRNGKey(2), (b, s, hkv, d))
    v = jax.random.normal(jax.random.PRNGKey(3), (b, s, hkv, d))
    ref = attn_mod.attention(q, k, v, impl="naive")
    if impl == "pallas":
        from repro.kernels import kernel_impl
        with kernel_impl("pallas_interpret"):
            out = attn_mod.attention(q, k, v, impl="pallas")
    else:
        out = attn_mod.attention(q, k, v, impl=impl, chunk=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("window", [16, 48, 500])
def test_windowed_attention_matches_naive(window):
    b, s, h, d = 1, 160, 2, 16
    q = jax.random.normal(jax.random.PRNGKey(4), (b, s, h, d))
    k = jax.random.normal(jax.random.PRNGKey(5), (b, s, h, d))
    v = jax.random.normal(jax.random.PRNGKey(6), (b, s, h, d))
    ref = attn_mod.attention(q, k, v, impl="naive", window=window)
    out = attn_mod.attention(q, k, v, impl="chunked", window=window,
                             chunk=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_attention_unroll_is_equivalent():
    """The dry-run cost-fidelity unroll changes HLO structure only."""
    b, s, h, d = 1, 128, 2, 16
    q = jax.random.normal(jax.random.PRNGKey(7), (b, s, h, d))
    k = jax.random.normal(jax.random.PRNGKey(8), (b, s, h, d))
    v = jax.random.normal(jax.random.PRNGKey(9), (b, s, h, d))
    for kw in (dict(impl="chunked", chunk=32),
               dict(impl="chunked", chunk=32, window=40)):
        a = attn_mod.attention(q, k, v, unroll=False, **kw)
        bb = attn_mod.attention(q, k, v, unroll=True, **kw)
        np.testing.assert_allclose(np.asarray(a), np.asarray(bb),
                                   rtol=1e-6, atol=1e-6)


def test_decode_attention_matches_full():
    b, s, h, hkv, d = 2, 40, 4, 2, 16
    q = jax.random.normal(jax.random.PRNGKey(10), (b, 1, h, d))
    kc = jax.random.normal(jax.random.PRNGKey(11), (b, 64, hkv, d))
    vc = jax.random.normal(jax.random.PRNGKey(12), (b, 64, hkv, d))
    out = attn_mod.decode_attention(q, kc, vc, length=s)
    # oracle: same computation with explicit slicing
    kk = jnp.repeat(kc[:, :s], 2, axis=2)
    vv = jnp.repeat(vc[:, :s], 2, axis=2)
    logit = jnp.einsum("bqhd,bkhd->bhqk", q, kk) * d ** -0.5
    p = jax.nn.softmax(logit, axis=-1)
    ref = jnp.einsum("bhqk,bkhd->bqhd", p, vv)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------
def test_moe_capacity_inf_matches_reference():
    from repro.models import ffn
    key = jax.random.PRNGKey(0)
    params = ffn.init_moe(key, 16, 32, n_experts=4)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16))
    ref = ffn.moe_ffn_reference(params, x, top_k=2)
    out = ffn.moe_ffn(params, x, top_k=2, capacity_factor=float("inf"),
                      compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_moe_capacity_drops_overflow_only():
    """Finite capacity output differs from oracle only on dropped tokens,
    and never produces NaNs."""
    from repro.models import ffn
    key = jax.random.PRNGKey(0)
    params = ffn.init_moe(key, 16, 32, n_experts=4)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, 16))
    out = ffn.moe_ffn(params, x, top_k=2, capacity_factor=1.0,
                      compute_dtype=jnp.float32)
    assert bool(jnp.all(jnp.isfinite(out)))
    assert out.shape == x.shape


# ---------------------------------------------------------------------------
# losses & numerics
# ---------------------------------------------------------------------------
def test_lm_loss_matches_dense_xent():
    cfg = smoke(get_config("qwen3-0.6b"))
    cfg = dataclasses.replace(cfg, compute_dtype="float32", loss_chunk=32)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    toks, labels = _inputs(cfg, b=2, s=16)
    hidden = M.forward(params, cfg, toks)
    loss = M.lm_loss(params, cfg, hidden, labels)
    logits = M.logits_fn(params, cfg, hidden)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    expect = jnp.mean(logz - gold)
    np.testing.assert_allclose(float(loss), float(expect), rtol=1e-5)


def test_int8_kv_cache_close_to_bf16():
    cfg = smoke(get_config("qwen1.5-32b"))
    cfg32 = dataclasses.replace(cfg, compute_dtype="float32",
                                kv_cache_dtype="float32")
    cfg8 = dataclasses.replace(cfg, compute_dtype="float32",
                               kv_cache_dtype="int8")
    params = M.init_params(cfg32, jax.random.PRNGKey(0))
    toks, _ = _inputs(cfg32, b=2, s=12)
    lf, cf = M.prefill(params, cfg32, toks, max_len=16)
    lq, cq = M.prefill(params, cfg8, toks, max_len=16)
    # int8 KV introduces bounded error on the next-token logits
    lf2, _ = M.decode_step(params, cfg32, cf, toks[:, -1])
    lq2, _ = M.decode_step(params, cfg8, cq, toks[:, -1])
    err = np.abs(np.asarray(lf2) - np.asarray(lq2))
    rel = err.max() / (np.abs(np.asarray(lf2)).max() + 1e-9)
    assert rel < 0.08, rel
