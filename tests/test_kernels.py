"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracle,
swept over shapes, dtypes and block sizes (assignment deliverable (c))."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import kernel_impl
from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.matmul import ops as mm_ops, ref as mm_ref
from repro.kernels.quant import ops as q_ops, ref as q_ref
from repro.kernels.rglru import ops as rg_ops, ref as rg_ref
from repro.kernels.rwkv6 import ops as wk_ops, ref as wk_ref


def _rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(jax.random.PRNGKey(key), shape) * scale) \
        .astype(dtype)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("m,k,n", [(32, 32, 32), (64, 96, 32), (128, 64, 96),
                                   (190, 210, 170),    # paper-style irregular
                                   (8, 256, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_shapes_dtypes(m, k, n, dtype):
    x = _rand(0, (m, k), dtype)
    y = _rand(1, (k, n), dtype)
    ref = mm_ref.matmul(x, y)
    out = mm_ops.matmul(x, y, bm=32, bn=32, bk=32, impl="pallas_interpret")
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("bm,bn,bk", [(16, 16, 16), (32, 64, 16),
                                      (64, 32, 128), (128, 128, 128)])
def test_matmul_block_shape_sweep(bm, bn, bk):
    """The solver's intra-tile choice must never change the function."""
    x = _rand(2, (96, 160))
    y = _rand(3, (160, 224))
    ref = np.asarray(x, np.float32) @ np.asarray(y, np.float32)
    out = mm_ops.matmul(x, y, bm=bm, bn=bn, bk=bk, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-5, atol=2e-5)


def test_matmul_padding_exactness():
    """Computation padding (zero rows/cols) must be exact for matmul."""
    x = _rand(4, (37, 53))
    y = _rand(5, (53, 41))
    out = mm_ops.matmul(x, y, bm=32, bn=32, bk=32, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(x) @ np.asarray(y),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("s,h,hkv,d", [(128, 4, 4, 32), (256, 4, 2, 32),
                                       (128, 8, 1, 64)])
def test_flash_attention_causal_gqa(s, h, hkv, d):
    b = 2
    q = _rand(10, (b, s, h, d))
    k = _rand(11, (b, s, hkv, d))
    v = _rand(12, (b, s, hkv, d))
    ref = fa_ops.flash_attention(q, k, v, causal=True, impl="xla")
    out = fa_ops.flash_attention(q, k, v, causal=True, bq=64, bk=64,
                                 impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("window", [32, 64, 100])
def test_flash_attention_sliding_window(window):
    b, s, h, d = 1, 256, 2, 32
    q = _rand(13, (b, s, h, d))
    k = _rand(14, (b, s, h, d))
    v = _rand(15, (b, s, h, d))
    ref = fa_ops.flash_attention(q, k, v, causal=True, window=window,
                                 impl="xla")
    out = fa_ops.flash_attention(q, k, v, causal=True, window=window,
                                 bq=64, bk=64, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_unpadded_seq():
    """Sequence padding inside ops.flash_attention is mask-exact."""
    b, s, h, d = 1, 100, 2, 32          # 100 % 64 != 0
    q = _rand(16, (b, s, h, d))
    k = _rand(17, (b, s, h, d))
    v = _rand(18, (b, s, h, d))
    ref = fa_ops.flash_attention(q, k, v, impl="xla")
    out = fa_ops.flash_attention(q, k, v, bq=64, bk=64,
                                 impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_bf16():
    b, s, h, d = 1, 128, 2, 32
    q = _rand(19, (b, s, h, d), jnp.bfloat16)
    k = _rand(20, (b, s, h, d), jnp.bfloat16)
    v = _rand(21, (b, s, h, d), jnp.bfloat16)
    ref = fa_ops.flash_attention(q, k, v, impl="xla")
    out = fa_ops.flash_attention(q, k, v, bq=64, bk=64,
                                 impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=3e-2, atol=3e-2)


# ---------------------------------------------------------------------------
# rglru
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,s,d,bs", [(2, 64, 16, 16), (1, 128, 32, 64),
                                      (3, 100, 8, 32)])   # 100 % 32 != 0
def test_rglru_matches_scan(b, s, d, bs):
    a = jax.nn.sigmoid(_rand(30, (b, s, d)))       # decay in (0,1)
    u = _rand(31, (b, s, d), scale=0.5)
    ref = rg_ref.rglru(a, u)
    out = rg_ops.rglru(a, u, bs=bs, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_rglru_state_carries_across_blocks():
    """Splitting the sequence into blocks must not reset the recurrence."""
    b, s, d = 1, 64, 8
    a = jnp.full((b, s, d), 0.9)
    u = jnp.ones((b, s, d))
    full = rg_ops.rglru(a, u, bs=64, impl="pallas_interpret")
    blocked = rg_ops.rglru(a, u, bs=16, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(full), np.asarray(blocked),
                               rtol=1e-6, atol=1e-6)
    # analytic fixed point: h_inf = 1 / (1 - 0.9) = 10
    assert np.asarray(full)[0, -1, 0] == pytest.approx(10.0, rel=1e-2)


# ---------------------------------------------------------------------------
# rwkv6
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("bh,s,dk,dv,bs", [(2, 64, 16, 16, 32),
                                           (4, 96, 8, 24, 32),
                                           (1, 50, 16, 16, 16)])
def test_rwkv6_matches_scan(bh, s, dk, dv, bs):
    r = _rand(40, (bh, s, dk), scale=0.5)
    k = _rand(41, (bh, s, dk), scale=0.5)
    v = _rand(42, (bh, s, dv), scale=0.5)
    w = jax.nn.sigmoid(_rand(43, (bh, s, dk)))     # decay in (0,1)
    u = _rand(44, (bh, dk), scale=0.5)
    ref = wk_ref.rwkv6(r, k, v, w, u)
    out = wk_ops.rwkv6(r, k, v, w, u, bs=bs, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_rwkv6_final_state_matches():
    bh, s, dk, dv = 2, 64, 8, 8
    r = _rand(45, (bh, s, dk), scale=0.5)
    k = _rand(46, (bh, s, dk), scale=0.5)
    v = _rand(47, (bh, s, dv), scale=0.5)
    w = jax.nn.sigmoid(_rand(48, (bh, s, dk)))
    u = _rand(49, (bh, dk), scale=0.5)
    _, st_ref = wk_ref.rwkv6(r, k, v, w, u, return_state=True)
    _, st_out = wk_ops.rwkv6(r, k, v, w, u, bs=32, impl="pallas_interpret",
                             return_state=True)
    np.testing.assert_allclose(np.asarray(st_out), np.asarray(st_ref),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# quant
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,d", [(64, 32), (100, 16), (256, 128)])
def test_quant_roundtrip(n, d):
    x = _rand(50, (n, d), scale=3.0)
    q, s = q_ops.quantize(x, bn=32, impl="pallas_interpret")
    qr, sr = q_ref.quantize(x)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    deq = q_ops.dequantize(q, s)
    err = np.abs(np.asarray(deq) - np.asarray(x))
    # quantization error bounded by scale/2 per element
    bound = np.asarray(s) * 0.5 + 1e-6
    assert (err <= bound).all()


def test_quant_int8_range():
    x = _rand(51, (32, 32), scale=100.0)
    q, _ = q_ops.quantize(x, impl="pallas_interpret")
    assert np.asarray(q).min() >= -127 and np.asarray(q).max() <= 127


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------
def test_dispatch_context_controls_impl():
    from repro.kernels import current_impl
    with kernel_impl("pallas_interpret"):
        assert current_impl() == "pallas_interpret"
        with kernel_impl("xla"):
            assert current_impl() == "xla"
        assert current_impl() == "pallas_interpret"


def test_dispatch_rejects_bad_impl():
    with pytest.raises(ValueError):
        with kernel_impl("cuda"):
            pass
