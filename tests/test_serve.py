"""Serving engine: batched generation, greedy consistency, throughput."""
from __future__ import annotations

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import smoke
from repro.models import model as M
from repro.serve.engine import Engine, ServeConfig, throughput_stats


@pytest.fixture(scope="module")
def engine():
    cfg = dataclasses.replace(smoke(get_config("qwen3-0.6b")),
                              compute_dtype="float32",
                              kv_cache_dtype="float32")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return Engine(cfg, params, ServeConfig(max_len=64))


def test_generate_shapes_and_determinism(engine):
    prompts = np.array([[1, 2, 3, 4], [5, 6, 7, 8]], np.int32)
    a = engine.generate(prompts, max_new_tokens=8)
    b = engine.generate(prompts, max_new_tokens=8)
    assert a.shape == (2, 8)
    np.testing.assert_array_equal(a, b)     # greedy is deterministic


def test_generate_matches_stepwise_decode(engine):
    """The engine's batched loop equals manual prefill + decode steps."""
    cfg, params = engine.cfg, engine.params
    prompts = np.array([[3, 1, 4, 1, 5]], np.int32)
    out = engine.generate(prompts, max_new_tokens=4)
    logits, cache = M.prefill(params, cfg, jax.numpy.asarray(prompts),
                              max_len=64)
    toks = []
    tok = np.argmax(np.asarray(logits), -1).astype(np.int32)
    for _ in range(4):
        toks.append(tok.copy())
        logits, cache = M.decode_step(params, cfg, cache,
                                      jax.numpy.asarray(tok))
        tok = np.argmax(np.asarray(logits), -1).astype(np.int32)
    np.testing.assert_array_equal(out[0], np.stack(toks, -1)[0])


def test_batch_order_invariance(engine):
    """Each slot's continuation is independent of its batch neighbours."""
    p1 = np.array([[1, 2, 3, 4]], np.int32)
    p2 = np.array([[9, 8, 7, 6]], np.int32)
    both = np.concatenate([p1, p2], 0)
    o_both = engine.generate(both, max_new_tokens=6)
    o_1 = engine.generate(p1, max_new_tokens=6)
    o_2 = engine.generate(p2, max_new_tokens=6)
    np.testing.assert_array_equal(o_both[0], o_1[0])
    np.testing.assert_array_equal(o_both[1], o_2[0])


def test_eos_stops_early():
    cfg = dataclasses.replace(smoke(get_config("qwen3-0.6b")),
                              compute_dtype="float32")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, ServeConfig(max_len=64, eos_id=0))
    prompts = np.array([[1, 2, 3, 4]], np.int32)
    out = eng.generate(prompts, max_new_tokens=16)
    if (out[0] == 0).any():
        first = int(np.argmax(out[0] == 0))
        assert (out[0, first + 1:] == 0).all()


def test_temperature_sampling_runs():
    cfg = dataclasses.replace(smoke(get_config("qwen3-0.6b")),
                              compute_dtype="float32")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, ServeConfig(max_len=64, temperature=1.0))
    out = eng.generate(np.array([[1, 2, 3, 4]], np.int32),
                       max_new_tokens=8)
    assert out.shape == (1, 8)
    assert (out >= 0).all() and (out < cfg.vocab).all()


def test_throughput_stats():
    s = throughput_stats(1000, 2.0)
    assert s["tokens_per_s"] == 500.0


# ---------------------------------------------------------------------------
# Plan serving: repeated requests hit the whole-plan compiled-program cache
# ---------------------------------------------------------------------------
def test_plan_engine_serves_from_program_cache():
    from repro.codegen import (allclose, cache_stats, clear_program_cache,
                               random_inputs, reference_executor)
    from repro.core import SolverOptions, THREE_SLICE, polybench, solve
    from repro.serve import PlanEngine

    g = polybench.build("2-madd")
    plan = solve(g, THREE_SLICE, SolverOptions(time_budget_s=2.0))
    ins = random_inputs(g, seed=0)
    ref = reference_executor(g)(ins)

    clear_program_cache()
    eng = PlanEngine(impl="xla")
    eng.register("2-madd", g, plan)
    cold = eng.warmup("2-madd", ins)
    assert cold >= 0.0
    assert cache_stats()["misses"] == 1

    out = eng.submit("2-madd", ins)             # steady-state request
    assert all(allclose(out[k], ref[k]) for k in ref)

    # a brand-new engine (new replica) still hits the same compiled program
    eng2 = PlanEngine(impl="xla")
    eng2.register("m", g, plan)
    out2 = eng2.submit("m", ins)
    assert all(allclose(out2[k], ref[k]) for k in ref)
    stats = eng2.stats()
    # exactly one compile ever; the replica's first submit is a cache hit
    # (later submits resolve engine-locally, no fingerprinting per request)
    assert stats["misses"] == 1 and stats["hits"] >= 1
    assert eng.stats()["requests"] == 2


def test_plan_engine_admission_evicts_lru_registration():
    from repro.codegen import clear_program_cache, random_inputs
    from repro.core import SolverOptions, THREE_SLICE, polybench, solve
    from repro.serve import PlanEngine, ServeConfig

    clear_program_cache()
    eng = PlanEngine(impl="xla", sc=ServeConfig(max_plans=2))
    graphs = {}
    for name in ("2-madd", "3-madd"):
        g = polybench.build(name)
        plan = solve(g, THREE_SLICE, SolverOptions(time_budget_s=1.0))
        graphs[name] = (g, plan)
        eng.register(name, g, plan)
    assert eng.names() == ["2-madd", "3-madd"]
    # 3-madd becomes most recently used; admitting a third plan evicts
    # the LRU registration (2-madd)
    eng.submit("3-madd", random_inputs(graphs["3-madd"][0], seed=0))
    g, plan = graphs["2-madd"]
    eng.register("copy", g, plan)
    assert eng.names() == ["3-madd", "copy"]


def test_plan_engine_stats_pools_and_hit_rate():
    from repro.codegen import clear_program_cache, random_inputs
    from repro.core import SolverOptions, THREE_SLICE, polybench, solve
    from repro.serve import PlanEngine, ServeConfig

    clear_program_cache()
    g = polybench.build("2-madd")
    plan = solve(g, THREE_SLICE, SolverOptions(time_budget_s=1.0))
    eng = PlanEngine(impl="xla", sc=ServeConfig(pool_size=2))
    eng.register("m", g, plan)
    ins = random_inputs(g, seed=0)
    for _ in range(3):
        eng.submit("m", ins)
    s = eng.stats()
    assert s["requests"] == 3 and s["per_name"] == {"m": 3}
    pool = s["pools"]["m/xla"]
    assert pool["pool_size"] == 2 and pool["calls"] == 3
    assert pool["next"] == 1                    # 3 calls round-robin of 2
    assert 0.0 <= s["hit_rate"] <= 1.0
    assert s["capacity"] >= 1 and "evictions" in s
    # entries detail rides along for dashboards
    assert any(e["pool_size"] == 2 for e in s["entries"].values())


def test_plan_engine_surfaces_trace_cache_stats():
    """stats() exposes the frontend trace cache feeding register_function:
    hits, size, and per-entry coverage of every cached lowering."""
    import jax.numpy as jnp

    from repro import frontend
    from repro.core import SolverOptions
    from repro.serve import PlanEngine

    frontend.clear_trace_cache()
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(8, 6)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(6, 5)).astype(np.float32))
    fn = lambda x, y: x @ y                     # noqa: E731

    eng = PlanEngine(impl="xla")
    eng.register_function("mm", fn, (a, b),
                          solver_opts=SolverOptions(time_budget_s=2.0))
    out = eng.submit("mm", (a, b))
    np.testing.assert_allclose(np.asarray(out), np.asarray(a @ b),
                               rtol=2e-4)

    tc = eng.stats()["trace_cache"]
    assert tc["size"] == 1 and tc["misses"] >= 1
    (entry,) = tc["entries"].values()           # fully covered single dot
    assert entry["n_supported"] == entry["n_eqns"] >= 1
    assert entry["coverage_eqns"] == 1.0
    assert entry["coverage_flops"] == 1.0

    # re-registering the same structure is a trace-cache hit, not a new
    # lowering — replicas share one record
    eng.register_function("mm2", fn, (a, b),
                          solver_opts=SolverOptions(time_budget_s=2.0))
    tc2 = eng.stats()["trace_cache"]
    assert tc2["hits"] > tc["hits"] and tc2["size"] == 1


def test_plan_engine_reasserts_its_pool_contract():
    """Another caller rebuilding the cache entry with a different pool must
    not silently downgrade an engine configured for a larger pool."""
    from repro.codegen import (clear_program_cache, compiled_program,
                               random_inputs)
    from repro.core import SolverOptions, THREE_SLICE, polybench, solve
    from repro.serve import PlanEngine, ServeConfig

    clear_program_cache()
    g = polybench.build("2-madd")
    plan = solve(g, THREE_SLICE, SolverOptions(time_budget_s=1.0))
    eng = PlanEngine(impl="xla", sc=ServeConfig(pool_size=2))
    eng.register("m", g, plan)
    ins = random_inputs(g, seed=0)
    eng.submit("m", ins)
    compiled_program(g, plan, "xla", pool_size=1)   # foreign rebuild
    eng.submit("m", ins)
    assert eng.stats()["pools"]["m/xla"]["pool_size"] == 2
