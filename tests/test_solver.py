"""Integration tests: NLP solver (core/solver.py) — paper §4 / §6."""
from __future__ import annotations

import pytest

from repro.core import (ONE_SLICE, THREE_SLICE, Hardware, SolverOptions,
                        polybench, solve)

FAST = SolverOptions(time_budget_s=10.0)


def _gf(plan):
    return plan.useful_flops / plan.latency_s / 1e9


@pytest.fixture(scope="module")
def plans_3mm():
    g = polybench.build("3mm")
    return {mode: solve(g, THREE_SLICE if mode == "prometheus" else ONE_SLICE,
                        SolverOptions(mode=mode, time_budget_s=20.0))
            for mode in ("prometheus", "sisyphus", "streamhls", "autodse")}


def test_all_modes_produce_feasible_plans(plans_3mm):
    for mode, plan in plans_3mm.items():
        assert plan.latency_s > 0, mode
        assert plan.configs, mode
        for tid, rep in plan.reports.items():
            assert rep.vmem_bytes <= ONE_SLICE.vmem * 3 + 1, (mode, tid)


def test_prometheus_dominates_restricted_modes(plans_3mm):
    """Paper Table 6: the full space at least matches every restriction."""
    p = _gf(plans_3mm["prometheus"])
    for mode in ("sisyphus", "streamhls", "autodse"):
        assert p >= _gf(plans_3mm[mode]) * 0.999, mode


def test_pragma_only_modes_are_far_slower(plans_3mm):
    """autodse/streamhls lack tiling -> orders of magnitude behind
    (paper: 1.74 / 174 GF/s vs 368 GF/s at FPGA scale)."""
    assert _gf(plans_3mm["prometheus"]) > 10 * _gf(plans_3mm["autodse"])
    assert _gf(plans_3mm["prometheus"]) > 10 * _gf(plans_3mm["streamhls"])


def test_sisyphus_joint_space_blowup(plans_3mm):
    """Table 10 story: the shared-buffer product space on 3mm is orders of
    magnitude larger than what the budget can cover -> timed_out."""
    sis = plans_3mm["sisyphus"]
    pro = plans_3mm["prometheus"]
    assert sis.space_size > 1e6
    assert sis.timed_out
    assert not pro.timed_out


def test_solver_deterministic():
    g = polybench.build("atax")
    a = solve(g, THREE_SLICE, SolverOptions(time_budget_s=8.0, seed=3))
    b = solve(g, THREE_SLICE, SolverOptions(time_budget_s=8.0, seed=3))
    assert a.latency_s == b.latency_s
    assert {t: c.to_jsonable() for t, c in a.configs.items()} == \
        {t: c.to_jsonable() for t, c in b.configs.items()}


def test_multi_slice_helps_compute_bound_not_memory_bound():
    """Paper Table 8 observation: 3 SLRs help 2mm/3mm (compute-bound),
    not atax/bicg (memory-bound).  TPU-scale datasets put the O(N)-reuse
    kernels in the paper's compute-bound regime (DESIGN.md §2)."""
    for name, expect_speedup in (("3mm", True), ("bicg", False)):
        g = polybench.build(name, scale=16)
        one = solve(g, ONE_SLICE, SolverOptions(time_budget_s=15.0))
        three = solve(g, THREE_SLICE, SolverOptions(time_budget_s=15.0))
        ratio = one.latency_s / three.latency_s
        if expect_speedup:
            assert ratio > 1.05, f"{name}: {ratio}"
        else:
            assert ratio < 1.5, f"{name}: {ratio}"


def test_vmem_constraint_respected_under_tiny_budget():
    g = polybench.build("gemm")
    tiny = Hardware.make(n_slices=1, vmem_frac=0.02)   # ~320 KiB
    plan = solve(g, tiny, SolverOptions(time_budget_s=8.0))
    for rep in plan.reports.values():
        assert rep.vmem_bytes <= tiny.slices[0].vmem + 1


def test_padding_only_in_padding_capable_modes():
    g = polybench.build("gemm")      # trip counts 200/220/240
    pro = solve(g, ONE_SLICE, SolverOptions(time_budget_s=10.0))
    sis = solve(g, ONE_SLICE, SolverOptions(mode="sisyphus",
                                            time_budget_s=10.0))
    for cfg in sis.configs.values():
        assert all(t.pad == 0 for t in cfg.tiles.values()), \
            "sisyphus mode must not pad"
    # prometheus may pad (not asserted — solver choice), but any padding
    # must keep tiles dividing the padded extent
    for cfg in pro.configs.values():
        for t in cfg.tiles.values():
            assert t.padded_tc % t.tile == 0


def test_concurrency_only_in_dataflow_modes():
    g = polybench.build("3mm")
    sis = solve(g, THREE_SLICE, SolverOptions(mode="sisyphus",
                                              time_budget_s=10.0))
    slices = {c.slice_id for c in sis.configs.values()}
    assert slices == {0}, "sisyphus is single-slice"


@pytest.mark.parametrize("name", ["2mm", "atax", "gesummv", "3-madd"])
def test_modes_solve_quickly_on_more_kernels(name):
    g = polybench.build(name)
    for mode in ("prometheus", "streamhls", "autodse"):
        plan = solve(g, THREE_SLICE, SolverOptions(mode=mode,
                                                   time_budget_s=10.0))
        assert plan.latency_s > 0
        assert plan.solver_seconds < 60
