"""Unit tests: HLO collective parsing + roofline arithmetic (launch/)."""
from __future__ import annotations

import pytest

from repro.configs import get_config
from repro.launch import hlo_parse
from repro.launch.roofline import RooflineReport, active_params, model_flops
from repro.core.resources import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

HLO = """
HloModule test
ENTRY main {
  %p0 = f32[128,256]{1,0} parameter(0)
  %ag = f32[512,256]{1,0} all-gather(%p0), replica_groups=[4,4], dimensions={0}
  %ar = bf16[128,256]{1,0} all-reduce(%x), to_apply=%add
  %rs = f32[32,256]{1,0} reduce-scatter(%p0), replica_groups=[2,4], dimensions={0}
  %aa = f32[128,256]{1,0} all-to-all(%p0), dimensions={0}
  %cp = bf16[64,64]{1,0} collective-permute(%y), source_target_pairs={{0,1}}
  %ags = (f32[128],f32[256]) all-gather-start(%z), dimensions={0}
  %agd = f32[256]{0} all-gather-done(%ags)
}
"""


def test_collective_bytes_by_op():
    per = hlo_parse.collective_bytes(HLO)
    assert per["all-gather"] == 512 * 256 * 4 + 256 * 4   # incl. async -done
    assert per["all-reduce"] == 128 * 256 * 2
    # reduce-scatter: shard result x group size = input bytes
    assert per["reduce-scatter"] == 32 * 256 * 4 * 4
    assert per["all-to-all"] == 128 * 256 * 4
    assert per["collective-permute"] == 64 * 64 * 2


def test_async_start_not_double_counted():
    per = hlo_parse.collective_bytes(HLO)
    # the -start op contributes nothing; only the -done result counts
    assert per["all-gather"] - (512 * 256 * 4) == 256 * 4


def test_no_collectives_in_plain_hlo():
    assert hlo_parse.total_collective_bytes(
        "%m = f32[8,8] multiply(%a, %b)") == 0


def test_roofline_terms_and_bound():
    rep = RooflineReport(
        arch="x", shape="train_4k", mesh="single", n_chips=256,
        flops_per_chip=PEAK_FLOPS_BF16,          # 1 s of compute
        hbm_bytes_per_chip=HBM_BW * 2,           # 2 s of memory
        coll_bytes_per_chip=ICI_BW * 0.5,        # 0.5 s of collectives
        model_flops_total=PEAK_FLOPS_BF16 * 256 * 0.5)
    assert rep.t_compute == pytest.approx(1.0)
    assert rep.t_memory == pytest.approx(2.0)
    assert rep.t_collective == pytest.approx(0.5)
    assert rep.bound == "memory"
    assert rep.step_time == pytest.approx(2.0)
    assert rep.useful_ratio == pytest.approx(0.5)
    # useful flops at the roofline step time over the fleet peak
    assert rep.roofline_fraction == pytest.approx(0.25)


def test_active_params_moe_scaling():
    moe = get_config("mixtral-8x7b")
    n_act = active_params(moe)
    dense_equiv = active_params(
        __import__("dataclasses").replace(moe, ffn="swiglu"))
    # top-2 of 8 experts: ffn part is 2x one expert = 2x the dense ffn
    assert n_act > dense_equiv
    # mixtral: ~13B active of 47B total
    assert 10e9 < n_act < 16e9


def test_model_flops_train_vs_serve():
    cfg = get_config("qwen3-0.6b")
    t = model_flops(cfg, 1000, "train")
    s = model_flops(cfg, 1000, "prefill")
    assert t == pytest.approx(3 * s)


def test_active_params_magnitudes():
    """Sanity-check N_active against the published model sizes."""
    # qwen3-moe-235b: 22B active
    n = active_params(get_config("qwen3-moe-235b-a22b"))
    assert 15e9 < n < 30e9
    # yi-34b dense
    n = active_params(get_config("yi-34b"))
    assert 28e9 < n < 40e9
    # qwen1.5-0.5b: lm_head makes small models top-heavy
    n = active_params(get_config("qwen1.5-0.5b"))
    assert 0.3e9 < n < 0.8e9
