"""Continuous-batching tier (``repro.serve.batching``): coalescing,
accounting, deadlines, backpressure, chaos resubmit, trace sharing.

The contract pinned down here: every request entering the bounded queue
ends in exactly one terminal counter (``ok``/``fallbacks``/``expired``/
``rejected``/``errors``), batched answers are bit-for-bit the answers the
``jax.jit`` oracle gives, and a whole-batch failure degrades to
per-request resubmission — never to dropped futures.
"""
from __future__ import annotations

import pathlib
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SolverOptions
from repro.frontend import batched_trace_index
from repro.ft import ChaosPlan, DeadlineExceeded, EngineOverloaded
from repro.serve import (BatchConfig, PlanEngine, ServeConfig,
                         bucket_sizes)

_ROOT = pathlib.Path(__file__).resolve().parent.parent
if str(_ROOT) not in sys.path:
    sys.path.insert(0, str(_ROOT))

from benchmarks.bench_concurrent import arrival_schedule  # noqa: E402

_RNG = np.random.default_rng(0)
_WA = jnp.asarray(_RNG.standard_normal((16, 16)).astype(np.float32) * 0.1)
_WB = jnp.asarray(_RNG.standard_normal((16, 16)).astype(np.float32) * 0.1)
_X = jnp.asarray(_RNG.standard_normal((8, 16)).astype(np.float32))


def _fanout(x):
    # x is multi-consumer -> a segment boundary -> a multi-segment program
    a = x @ _WA
    b = x @ _WB
    return a * b + x


def _engine(sc: ServeConfig | None = None, **batch_kw) -> PlanEngine:
    if sc is None:
        sc = ServeConfig(batching=BatchConfig(**batch_kw))
    eng = PlanEngine(sc=sc)
    tf = eng.register_function(
        "f", _fanout, (_X,),
        solver_opts=SolverOptions(time_budget_s=0.5))
    assert tf is not None, "trace/solve must succeed (not degraded mode)"
    return eng


def _inputs(n: int):
    rng = np.random.default_rng(1)
    return [jnp.asarray(rng.standard_normal(_X.shape).astype(np.float32))
            for _ in range(n)]


# ---------------------------------------------------------------------------
# Bucket ladder
# ---------------------------------------------------------------------------
def test_bucket_sizes_ladder():
    assert bucket_sizes(1) == (1,)
    assert bucket_sizes(8) == (1, 2, 4, 8)
    assert bucket_sizes(7) == (1, 2, 4)    # rounds down to powers of two
    with pytest.raises(ValueError):
        bucket_sizes(0)
    assert BatchConfig(max_batch=16).buckets == (1, 2, 4, 8, 16)


# ---------------------------------------------------------------------------
# Open-loop arrival generator (the benchmark's determinism contract)
# ---------------------------------------------------------------------------
def test_arrival_schedule_is_deterministic():
    a = arrival_schedule(100, 50.0, seed=7)
    b = arrival_schedule(100, 50.0, seed=7)
    np.testing.assert_array_equal(a, b)
    assert len(a) == 100
    assert np.all(np.diff(a) >= 0)              # cumulative offsets
    assert np.all(a > 0)
    c = arrival_schedule(100, 50.0, seed=8)
    assert not np.array_equal(a, c)


def test_arrival_schedule_mean_rate_and_validation():
    sched = arrival_schedule(4000, 100.0, seed=0)
    # mean inter-arrival of Exp(rate) is 1/rate; 4000 samples pin it well
    assert sched[-1] / 4000 == pytest.approx(1 / 100.0, rel=0.1)
    assert len(arrival_schedule(0, 10.0)) == 0
    with pytest.raises(ValueError):
        arrival_schedule(-1, 10.0)
    with pytest.raises(ValueError):
        arrival_schedule(10, 0.0)


# ---------------------------------------------------------------------------
# End-to-end: batched answers == oracle answers, accounting closes
# ---------------------------------------------------------------------------
def test_batched_results_match_oracle_and_accounting_closes():
    eng = _engine(max_batch=4, max_wait_s=0.001)
    try:
        oracle = jax.jit(_fanout)
        xs = _inputs(20)
        futs = [eng.submit_async("f", (x,)) for x in xs]
        outs = [f.result(timeout=120) for f in futs]
        for x, out in zip(xs, outs):
            np.testing.assert_allclose(out, oracle(x),
                                       rtol=2e-4, atol=1e-5)
        st = eng.stats()["batching"]
        assert st["enqueued"] == 20
        assert st["completed"] == 20
        assert st["ok"] + st["fallbacks"] == st["completed"]
        assert (st["completed"] + st["expired"] + st["errors"]
                == st["enqueued"])
        assert st["rejected"] == 0 and st["errors"] == 0
    finally:
        eng.shutdown()


def test_coalescing_reduces_engine_dispatches():
    eng = _engine(max_batch=8, max_wait_s=0.2)
    try:
        eng.batcher().warmup("f")
        base = eng.stats()["requests"]
        futs = [eng.submit_async("f", (_X,)) for _ in range(32)]
        for f in futs:
            f.result(timeout=120)
        used = eng.stats()["requests"] - base
        # each flush is ONE engine submit; coalescing must beat 1:1
        assert used < 32
        st = eng.stats()["batching"]
        flushes = sum(b["flushes"] for b in st["buckets"].values())
        requests = sum(b["requests"] for b in st["buckets"].values())
        assert requests == 32 and flushes < 32
        assert any(int(k) > 1 for k in st["buckets"])  # real coalescing
    finally:
        eng.shutdown()


# ---------------------------------------------------------------------------
# Failure paths
# ---------------------------------------------------------------------------
def test_batch_failure_resubmits_every_request():
    cp = ChaosPlan(batch_fail_at=(0,))
    sc = ServeConfig(chaos=cp,
                     batching=BatchConfig(max_batch=4, max_wait_s=0.001))
    eng = _engine(sc=sc)
    try:
        oracle = jax.jit(_fanout)
        xs = _inputs(8)
        futs = [eng.submit_async("f", (x,)) for x in xs]
        outs = [f.result(timeout=120) for f in futs]
        for x, out in zip(xs, outs):       # no request lost to the fault
            np.testing.assert_allclose(out, oracle(x),
                                       rtol=2e-4, atol=1e-5)
        st = eng.stats()["batching"]
        assert st["batch_failures"] >= 1
        assert st["resubmitted"] >= 1
        assert st["completed"] == 8 and st["errors"] == 0
    finally:
        eng.shutdown()


def test_expired_deadline_rejects_with_deadline_exceeded():
    eng = _engine(max_batch=2, max_wait_s=0.001)
    try:
        fut = eng.submit_async("f", (_X,), deadline_s=0.0)
        with pytest.raises(DeadlineExceeded):
            fut.result(timeout=60)
        st = eng.stats()["batching"]
        assert st["expired"] >= 1
        assert (st["completed"] + st["expired"] + st["errors"]
                == st["enqueued"])
    finally:
        eng.shutdown()


def test_full_queue_rejects_and_shutdown_drains():
    sc = ServeConfig(batching=BatchConfig(
        max_batch=8, max_wait_s=5.0, max_queue=2))
    eng = _engine(sc=sc)
    b = eng.batcher()
    # two requests sit in a partial bucket (max_wait far away); the third
    # must be rejected at admission, not silently queued
    f1 = b.submit("f", (_X,))
    f2 = b.submit("f", (_X,))
    with pytest.raises(EngineOverloaded):
        b.submit("f", (_X,))
    assert eng.stats()["batching"]["rejected"] == 1
    eng.shutdown()                      # drains the queue before exiting
    oracle = jax.jit(_fanout)
    np.testing.assert_allclose(f1.result(timeout=5), oracle(_X),
                               rtol=2e-4, atol=1e-5)
    np.testing.assert_allclose(f2.result(timeout=5), oracle(_X),
                               rtol=2e-4, atol=1e-5)


def test_unknown_entry_rejected_at_submit():
    eng = _engine(max_batch=2)
    try:
        with pytest.raises(KeyError):
            eng.batcher().submit("nope", (_X,))
        with pytest.raises(ValueError):
            # wrong shape: caller contract error, raised synchronously
            eng.batcher().submit("f", (jnp.zeros((3, 16)),))
    finally:
        eng.shutdown()


# ---------------------------------------------------------------------------
# Trace/program sharing and the non-batched engine flavor
# ---------------------------------------------------------------------------
def test_bucket_traces_are_shared_and_memoized():
    eng = _engine(max_batch=4)
    try:
        tf = eng._functions["f"]
        assert tf.batched(2) is tf.batched(2)     # per-instance memo
        eng.batcher().warmup("f", buckets=(2,))
        assert "f@b2" in eng.stats()["functions"]
        idx = batched_trace_index()
        assert any(bucket == 2 for (_, bucket) in idx), (
            "batched re-trace must be indexed by (fingerprint, bucket) "
            "for cross-engine reuse")
    finally:
        eng.shutdown()


def test_submit_async_without_batching_is_inline():
    eng = PlanEngine(sc=ServeConfig())
    try:
        tf = eng.register_function(
            "f", _fanout, (_X,),
            solver_opts=SolverOptions(time_budget_s=0.5))
        assert tf is not None
        with pytest.raises(RuntimeError):
            eng.batcher()               # batching not configured
        fut = eng.submit_async("f", (_X,))
        assert fut.done()               # inline: already resolved
        np.testing.assert_allclose(fut.result(), jax.jit(_fanout)(_X),
                                   rtol=2e-4, atol=1e-5)
        assert eng.stats()["batching"] is None
    finally:
        eng.shutdown()
