"""CI bench gate (scripts/bench_compare.py): regression and floor logic."""
from __future__ import annotations

import importlib.util
import json
import pathlib

import pytest

_SPEC = importlib.util.spec_from_file_location(
    "bench_compare",
    pathlib.Path(__file__).resolve().parent.parent / "scripts"
    / "bench_compare.py")
bench_compare = importlib.util.module_from_spec(_SPEC)
_SPEC.loader.exec_module(bench_compare)


def _bench(kernels: dict) -> dict:
    return {"benchmark": "codegen_whole_plan", "kernels": kernels,
            "gmean_speedup": 0.0}


def _k(speedup: float, validated: bool = True) -> dict:
    return {"speedup": speedup, "validated": validated}


def test_gate_passes_on_equal_runs():
    base = _bench({"a": _k(2.0), "b": _k(1.2)})
    assert bench_compare.compare(base, base) == []


def test_gate_passes_within_noise_band():
    base = _bench({"a": _k(2.0)})
    fresh = _bench({"a": _k(1.85)})            # -7.5% < 10%
    assert bench_compare.compare(base, fresh) == []


def test_gate_fails_kernel_regression():
    base = _bench({"a": _k(2.0), "b": _k(1.2)})
    fresh = _bench({"a": _k(1.0), "b": _k(1.2)})
    failures = bench_compare.compare(base, fresh)
    assert any("a: speedup regressed" in f for f in failures)


def test_gate_fails_gmean_regression_only_when_aggregate_slips():
    base = _bench({k: _k(1.0) for k in "abcde"})
    # every kernel down 9.9% — inside the per-kernel band, but the gmean
    # (also -9.9%) is inside its 15% band too: passes
    fresh = _bench({k: _k(0.901) for k in "abcde"})
    assert bench_compare.compare(base, fresh) == []
    fresh = _bench({k: _k(0.80) for k in "abcde"})
    failures = bench_compare.compare(base, fresh,
                                     max_kernel_regress=0.25)
    assert failures and all("gmean" in f for f in failures)


def test_gate_fails_on_unvalidated_kernel():
    base = _bench({"a": _k(1.0)})
    fresh = _bench({"a": _k(5.0, validated=False)})
    failures = bench_compare.compare(base, fresh)
    assert any("validated=false" in f for f in failures)


def test_gate_enforces_absolute_floor():
    base = _bench({"gemver": _k(0.546)})
    fresh = _bench({"gemver": _k(0.60)})       # improved, but under floor
    failures = bench_compare.compare(base, fresh,
                                     floors={"gemver": 0.9})
    assert any("below floor" in f for f in failures)
    ok = _bench({"gemver": _k(0.95)})
    assert bench_compare.compare(base, ok, floors={"gemver": 0.9}) == []


def test_gate_ignores_added_kernels_in_gmean():
    base = _bench({"a": _k(1.0)})
    fresh = _bench({"a": _k(1.0), "zzz": _k(0.1)})
    assert bench_compare.compare(base, fresh) == []


def test_gate_flags_missing_kernels():
    base = _bench({"a": _k(1.0), "b": _k(1.0)})
    fresh = _bench({"a": _k(1.0)})
    failures = bench_compare.compare(base, fresh)
    assert any("missing" in f for f in failures)


def test_cli_roundtrip(tmp_path):
    base = tmp_path / "base.json"
    fresh = tmp_path / "fresh.json"
    base.write_text(json.dumps(_bench({"a": _k(1.0)})))
    fresh.write_text(json.dumps(_bench({"a": _k(0.5)})))
    assert bench_compare.main([str(base), str(fresh)]) == 1
    fresh.write_text(json.dumps(_bench({"a": _k(1.05)})))
    assert bench_compare.main([str(base), str(fresh)]) == 0


def test_committed_baseline_is_gateable():
    """The repo's committed BENCH_codegen.json must satisfy the gate's own
    acceptance floors (gemver >= 0.9x, all kernels validated)."""
    path = pathlib.Path(__file__).resolve().parent.parent \
        / "BENCH_codegen.json"
    if not path.exists():
        pytest.skip("no committed baseline")
    data = json.loads(path.read_text())
    failures = bench_compare.compare(data, data, floors={"gemver": 0.9})
    assert failures == []
