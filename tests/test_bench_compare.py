"""CI bench gate (scripts/bench_compare.py): regression and floor logic."""
from __future__ import annotations

import importlib.util
import json
import pathlib

import pytest

_SPEC = importlib.util.spec_from_file_location(
    "bench_compare",
    pathlib.Path(__file__).resolve().parent.parent / "scripts"
    / "bench_compare.py")
bench_compare = importlib.util.module_from_spec(_SPEC)
_SPEC.loader.exec_module(bench_compare)


def _bench(kernels: dict) -> dict:
    return {"benchmark": "codegen_whole_plan", "kernels": kernels,
            "gmean_speedup": 0.0}


def _k(speedup: float, validated: bool = True) -> dict:
    return {"speedup": speedup, "validated": validated}


def test_gate_passes_on_equal_runs():
    base = _bench({"a": _k(2.0), "b": _k(1.2)})
    assert bench_compare.compare(base, base) == []


def test_gate_passes_within_noise_band():
    base = _bench({"a": _k(2.0)})
    fresh = _bench({"a": _k(1.85)})            # -7.5% < 10%
    assert bench_compare.compare(base, fresh) == []


def test_gate_fails_kernel_regression():
    base = _bench({"a": _k(2.0), "b": _k(1.2)})
    fresh = _bench({"a": _k(1.0), "b": _k(1.2)})
    failures = bench_compare.compare(base, fresh)
    assert any("a: speedup regressed" in f for f in failures)


def test_gate_fails_gmean_regression_only_when_aggregate_slips():
    base = _bench({k: _k(1.0) for k in "abcde"})
    # every kernel down 9.9% — inside the per-kernel band, but the gmean
    # (also -9.9%) is inside its 15% band too: passes
    fresh = _bench({k: _k(0.901) for k in "abcde"})
    assert bench_compare.compare(base, fresh) == []
    fresh = _bench({k: _k(0.80) for k in "abcde"})
    failures = bench_compare.compare(base, fresh,
                                     max_kernel_regress=0.25)
    assert failures and all("gmean" in f for f in failures)


def test_gate_fails_on_unvalidated_kernel():
    base = _bench({"a": _k(1.0)})
    fresh = _bench({"a": _k(5.0, validated=False)})
    failures = bench_compare.compare(base, fresh)
    assert any("validated=false" in f for f in failures)


def test_gate_enforces_absolute_floor():
    base = _bench({"gemver": _k(0.546)})
    fresh = _bench({"gemver": _k(0.60)})       # improved, but under floor
    failures = bench_compare.compare(base, fresh,
                                     floors={"gemver": 0.9})
    assert any("below floor" in f for f in failures)
    ok = _bench({"gemver": _k(0.95)})
    assert bench_compare.compare(base, ok, floors={"gemver": 0.9}) == []


def test_gate_ignores_added_kernels_in_gmean():
    base = _bench({"a": _k(1.0)})
    fresh = _bench({"a": _k(1.0), "zzz": _k(0.1)})
    assert bench_compare.compare(base, fresh) == []


def test_gate_flags_missing_kernels():
    base = _bench({"a": _k(1.0), "b": _k(1.0)})
    fresh = _bench({"a": _k(1.0)})
    failures = bench_compare.compare(base, fresh)
    assert any("missing" in f for f in failures)


def test_cli_roundtrip(tmp_path):
    base = tmp_path / "base.json"
    fresh = tmp_path / "fresh.json"
    base.write_text(json.dumps(_bench({"a": _k(1.0)})))
    fresh.write_text(json.dumps(_bench({"a": _k(0.5)})))
    assert bench_compare.main([str(base), str(fresh)]) == 1
    fresh.write_text(json.dumps(_bench({"a": _k(1.05)})))
    assert bench_compare.main([str(base), str(fresh)]) == 0


def test_committed_baseline_is_gateable():
    """The repo's committed BENCH_codegen.json must satisfy the gate's own
    acceptance floors (gemver >= 0.9x, all kernels validated)."""
    path = pathlib.Path(__file__).resolve().parent.parent \
        / "BENCH_codegen.json"
    if not path.exists():
        pytest.skip("no committed baseline")
    data = json.loads(path.read_text())
    failures = bench_compare.compare(data, data, floors={"gemver": 0.9})
    assert failures == []


# ---------------------------------------------------------------------------
# Concurrent-serving gate
# ---------------------------------------------------------------------------
def _cbench(pools: dict, norm: str = "1") -> dict:
    return {"benchmark": "concurrent_serving",
            "scaling_baseline_pool": norm, "pools": pools}


def _pool(scaling: float, *, validated: bool = True, lost: int = 0,
          errors: list | None = None, rps: float = 1000.0) -> dict:
    return {"throughput_rps": rps, "scaling_vs_first": scaling,
            "validated": validated, "lost_updates": lost,
            "errors": errors or []}


def test_concurrent_gate_passes_on_equal_runs():
    base = _cbench({"1": _pool(1.0), "2": _pool(1.1), "4": _pool(1.3)})
    assert bench_compare.compare_concurrent(base, base) == []


def test_concurrent_gate_uses_scaling_not_absolute_throughput():
    """A 10x slower runner with the same pool scaling must pass."""
    base = _cbench({"1": _pool(1.0, rps=5000), "4": _pool(1.3, rps=6500)})
    fresh = _cbench({"1": _pool(1.0, rps=500), "4": _pool(1.25, rps=625)})
    assert bench_compare.compare_concurrent(base, fresh) == []


def test_concurrent_gate_fails_scaling_regression():
    base = _cbench({"1": _pool(1.0), "4": _pool(1.3)})
    fresh = _cbench({"1": _pool(1.0), "4": _pool(1.0)})   # -23%
    failures = bench_compare.compare_concurrent(base, fresh)
    assert any("pool 4: concurrent scaling regressed" in f
               for f in failures)


def test_concurrent_gate_rejects_mismatched_normalization():
    """scaling_vs_first ratios from runs normalized against different
    first pools must not be compared."""
    base = _cbench({"2": _pool(1.0), "4": _pool(1.3)}, norm="1")
    fresh = _cbench({"2": _pool(1.0), "4": _pool(1.3)}, norm="2")
    failures = bench_compare.compare_concurrent(base, fresh)
    assert any("normalized against different pools" in f for f in failures)
    # legacy files without the field still compare (no false failure)
    base.pop("scaling_baseline_pool")
    assert bench_compare.compare_concurrent(base, fresh) == []


def test_concurrent_gate_fails_on_lost_updates_or_errors():
    base = _cbench({"1": _pool(1.0)})
    fresh = _cbench({"1": _pool(1.0, lost=3)})
    assert any("lost updates" in f for f in
               bench_compare.compare_concurrent(base, fresh))
    fresh = _cbench({"1": _pool(1.0, errors=["thread 2: KeyError"])})
    assert any("worker errors" in f for f in
               bench_compare.compare_concurrent(base, fresh))
    fresh = _cbench({"1": _pool(1.0, validated=False)})
    assert any("validated=false" in f for f in
               bench_compare.compare_concurrent(base, fresh))


def test_concurrent_correctness_failures_exit_2(tmp_path):
    """Correctness failures (the never-retry class) exit with code 2;
    scaling-only failures exit 1 — the machine contract CI's retry logic
    branches on."""
    base = _cbench({"1": _pool(1.0), "4": _pool(1.3)})
    cbase = tmp_path / "b.json"
    cfresh = tmp_path / "f.json"
    cbase.write_text(json.dumps(base))
    argv = ["--concurrent-baseline", str(cbase),
            "--concurrent-fresh", str(cfresh)]
    cfresh.write_text(json.dumps(
        _cbench({"1": _pool(1.0), "4": _pool(1.3, lost=2)})))
    assert bench_compare.main(argv) == 2
    cfresh.write_text(json.dumps(
        _cbench({"1": _pool(1.0), "4": _pool(0.9)})))
    assert bench_compare.main(argv) == 1


def test_concurrent_cli(tmp_path):
    cbase = tmp_path / "cbase.json"
    cfresh = tmp_path / "cfresh.json"
    cbase.write_text(json.dumps(_cbench({"1": _pool(1.0),
                                         "4": _pool(1.3)})))
    cfresh.write_text(json.dumps(_cbench({"1": _pool(1.0),
                                          "4": _pool(0.9)})))
    argv = ["--concurrent-baseline", str(cbase),
            "--concurrent-fresh", str(cfresh)]
    assert bench_compare.main(argv) == 1
    cfresh.write_text(json.dumps(_cbench({"1": _pool(1.0),
                                          "4": _pool(1.28)})))
    assert bench_compare.main(argv) == 0
    # both gates in one invocation
    kbase = tmp_path / "kbase.json"
    kbase.write_text(json.dumps(_bench({"a": _k(1.0)})))
    assert bench_compare.main([str(kbase), str(kbase)] + argv) == 0


def test_committed_concurrent_baseline_is_gateable():
    """The committed BENCH_concurrent.json must pass its own gate: every
    pool validated, zero lost updates (the measured thread-safety
    answer stays green)."""
    path = pathlib.Path(__file__).resolve().parent.parent \
        / "BENCH_concurrent.json"
    if not path.exists():
        pytest.skip("no committed concurrent baseline")
    data = json.loads(path.read_text())
    assert bench_compare.compare_concurrent(data, data) == []


# ---------------------------------------------------------------------------
# Frontend trace gate
# ---------------------------------------------------------------------------
def _fbench(workloads: dict) -> dict:
    return {"benchmark": "frontend_trace", "workloads": workloads,
            "gmean_ratio": 0.0}


def _w(ratio: float, validated: bool = True, cov_e: float = 1.0,
       cov_f: float = 1.0) -> dict:
    return {"ratio": ratio, "validated": validated,
            "coverage_eqns": cov_e, "coverage_flops": cov_f}


def test_frontend_gate_passes_on_equal_runs():
    base = _fbench({"gemm_chain": _w(1.12), "mlp_block": _w(0.96,
                                                            cov_f=0.99)})
    assert bench_compare.compare_frontend(base, base) == []


def test_frontend_gate_fails_validation_with_correctness_tag():
    base = _fbench({"gemm_chain": _w(1.1)})
    fresh = _fbench({"gemm_chain": _w(1.1, validated=False)})
    failures = bench_compare.compare_frontend(base, fresh)
    assert failures and all(
        f.startswith(bench_compare.CORRECTNESS_TAG) for f in failures)


def test_frontend_gate_fails_coverage_drop_with_correctness_tag():
    base = _fbench({"mlp_block": _w(1.1, cov_f=0.99)})
    fresh = _fbench({"mlp_block": _w(1.1, cov_f=0.80)})
    failures = bench_compare.compare_frontend(base, fresh)
    assert any("coverage_flops dropped" in f for f in failures)
    assert all(f.startswith(bench_compare.CORRECTNESS_TAG) for f in failures)


def test_frontend_gate_hard_floors():
    base = _fbench({"gemm_chain": _w(1.1), "mlp_block": _w(1.1)})
    # the floors are absolute, not baseline-relative: a workload inside
    # the noise band (>= 0.95) passes as long as the gmean holds >= 1.0
    ok = _fbench({"gemm_chain": _w(1.10), "mlp_block": _w(0.96)})
    assert bench_compare.compare_frontend(base, ok) == []
    # one workload losing outright trips the per-workload floor
    failures = bench_compare.compare_frontend(
        base, _fbench({"gemm_chain": _w(1.30), "mlp_block": _w(0.90)}))
    assert any("per-workload floor" in f for f in failures)
    # everything in the noise band but the gmean below 1.0 trips the
    # gmean floor: the traced program must not lose to jax.jit overall
    failures = bench_compare.compare_frontend(
        base, _fbench({"gemm_chain": _w(0.97), "mlp_block": _w(0.96)}))
    assert any("gmean" in f for f in failures)
    # floors are tunable
    assert bench_compare.compare_frontend(
        base, _fbench({"gemm_chain": _w(0.97), "mlp_block": _w(0.96)}),
        gmean_floor=0.9, workload_floor=0.9) == []


def test_frontend_cli(tmp_path):
    fbase = tmp_path / "fbase.json"
    ffresh = tmp_path / "ffresh.json"
    fbase.write_text(json.dumps(_fbench({"gemm_chain": _w(1.1)})))
    argv = ["--frontend-baseline", str(fbase),
            "--frontend-fresh", str(ffresh)]
    ffresh.write_text(json.dumps(
        _fbench({"gemm_chain": _w(1.1, validated=False)})))
    assert bench_compare.main(argv) == 2          # correctness: no retry
    ffresh.write_text(json.dumps(_fbench({"gemm_chain": _w(0.9)})))
    assert bench_compare.main(argv) == 1          # timing: retryable
    ffresh.write_text(json.dumps(_fbench({"gemm_chain": _w(1.05)})))
    assert bench_compare.main(argv) == 0


def test_committed_frontend_baseline_is_gateable():
    """The committed BENCH_frontend.json must pass its own gate: every
    workload validated against the jax.jit oracle."""
    path = pathlib.Path(__file__).resolve().parent.parent \
        / "BENCH_frontend.json"
    if not path.exists():
        pytest.skip("no committed frontend baseline")
    data = json.loads(path.read_text())
    assert bench_compare.compare_frontend(data, data) == []


# ---------------------------------------------------------------------------
# Chaos gate: availability floors, recovery, artifact round trip
# ---------------------------------------------------------------------------
def _chaos(clean_avail=1.0, faulted_avail=1.0, *, recovered=True,
           injected=(("compile", "m", 1),), artifacts=True) -> dict:
    def scenario(avail):
        return {"requests": 60, "correct": int(60 * avail),
                "availability": avail, "p50_ms": 10.0, "p99_ms": 30.0,
                "errors": [], "failures": 2, "fallbacks": 2,
                "breaker_closed_after_recovery": recovered,
                "final_state": "ok" if recovered else "quarantined"}
    s_clean, s_faulted = scenario(clean_avail), scenario(faulted_avail)
    s_faulted["injected"] = [list(e) for e in injected]
    return {"benchmark": "chaos_serving",
            "scenarios": {"clean": s_clean, "faulted": s_faulted},
            "artifact_recovery": {"survived_corrupt_load": artifacts,
                                  "quarantined": artifacts,
                                  "regenerated": artifacts}}


def test_chaos_gate_passes_on_healthy_run():
    assert bench_compare.compare_chaos(_chaos()) == []


def test_chaos_gate_fails_availability_below_floor_as_correctness():
    failures = bench_compare.compare_chaos(_chaos(faulted_avail=0.95))
    assert any("chaos/faulted" in f and "availability" in f
               for f in failures)
    assert all(f.startswith(bench_compare.CORRECTNESS_TAG)
               for f in failures)
    # the floor is configurable
    assert bench_compare.compare_chaos(
        _chaos(faulted_avail=0.95), availability_floor=0.9) == []


def test_chaos_gate_fails_when_breaker_stays_open():
    failures = bench_compare.compare_chaos(_chaos(recovered=False))
    assert any("breaker did not close" in f for f in failures)
    # recovery timing can be runner noise: NOT correctness-tagged
    assert not any(f.startswith(bench_compare.CORRECTNESS_TAG)
                   for f in failures)


def test_chaos_gate_fails_when_nothing_was_injected():
    failures = bench_compare.compare_chaos(_chaos(injected=()))
    assert any("no faults were actually injected" in f for f in failures)


def test_chaos_gate_fails_on_artifact_recovery():
    failures = bench_compare.compare_chaos(_chaos(artifacts=False))
    assert sum("artifact recovery failed" in f for f in failures) == 3


def test_chaos_cli_exit_codes(tmp_path):
    good, bad = tmp_path / "good.json", tmp_path / "bad.json"
    good.write_text(json.dumps(_chaos()))
    bad.write_text(json.dumps(_chaos(faulted_avail=0.5)))
    assert bench_compare.main(["--chaos-fresh", str(good)]) == 0
    # availability misses are correctness failures: exit 2, never retried
    assert bench_compare.main(["--chaos-fresh", str(bad)]) == 2


def test_committed_chaos_baseline_is_gateable():
    """The committed BENCH_chaos.json must pass its own gate: the
    resilience contract held when the artifact was generated."""
    path = pathlib.Path(__file__).resolve().parent.parent \
        / "BENCH_chaos.json"
    if not path.exists():
        pytest.skip("no committed chaos baseline")
    data = json.loads(path.read_text())
    assert bench_compare.compare_chaos(data) == []


# ---------------------------------------------------------------------------
# Continuous-batching gate: open-loop throughput ratio + accounting
# ---------------------------------------------------------------------------
def _mode(rps, *, issued=200, fallbacks=0, expired=0, rejected=0,
          errors=0, ok=None, validated=True, p99=50.0) -> dict:
    if ok is None:
        ok = issued - fallbacks - expired - rejected - errors
    return {"throughput_rps": rps, "issued": issued, "ok": ok,
            "fallbacks": fallbacks, "expired": expired,
            "rejected": rejected, "errors": errors,
            "validated": validated, "p99_ms": p99}


def _rate(seq_rps, bat_rps, **batched_kw) -> dict:
    return {"offered_rps": 1000.0,
            "sequential": _mode(seq_rps),
            "batched": _mode(bat_rps, **batched_kw),
            "batched_vs_sequential": bat_rps / seq_rps}


def _obench(rates=None, gate_rate="2.0x", deadline_ms=2000.0) -> dict:
    if rates is None:
        rates = {"0.8x": _rate(1000.0, 1100.0),
                 "2.0x": _rate(1000.0, 2000.0)}
    return {"rates": rates, "gate_rate": gate_rate,
            "deadline_ms": deadline_ms, "capacity_rps": 1250.0}


def test_batching_gate_passes_on_healthy_run():
    assert bench_compare.compare_batching(_obench()) == []


def test_batching_gate_fails_speedup_below_floor_retryably():
    fresh = _obench({"2.0x": _rate(1000.0, 1100.0)})   # 1.1x < 1.2x
    failures = bench_compare.compare_batching(fresh)
    assert any("below the 1.20x floor" in f for f in failures)
    # throughput is runner noise territory: retryable, NOT tagged
    assert not any(f.startswith(bench_compare.CORRECTNESS_TAG)
                   for f in failures)
    assert bench_compare.compare_batching(fresh, speedup_floor=1.0) == []


def test_batching_gate_accounting_violation_is_correctness():
    bad = _obench({"2.0x": _rate(1000.0, 2000.0, ok=150)})  # 50 vanished
    failures = bench_compare.compare_batching(bad)
    assert any("request accounting broken" in f for f in failures)
    assert all(f.startswith(bench_compare.CORRECTNESS_TAG)
               for f in failures)


def test_batching_gate_errors_and_validation_are_correctness():
    failures = bench_compare.compare_batching(
        _obench({"2.0x": _rate(1000.0, 2000.0, errors=2, ok=198)}))
    assert any("request errors" in f for f in failures)
    assert all(f.startswith(bench_compare.CORRECTNESS_TAG)
               for f in failures)
    failures = bench_compare.compare_batching(
        _obench({"2.0x": _rate(1000.0, 2000.0, validated=False)}))
    assert any("oracle validation" in f for f in failures)
    assert all(f.startswith(bench_compare.CORRECTNESS_TAG)
               for f in failures)


def test_batching_gate_missing_mode_or_rates_is_correctness():
    rate = {"offered_rps": 1000.0, "sequential": _mode(1000.0),
            "batched_vs_sequential": 0.0}
    failures = bench_compare.compare_batching(_obench({"2.0x": rate}))
    assert any("mode 'batched' missing" in f
               and f.startswith(bench_compare.CORRECTNESS_TAG)
               for f in failures)
    failures = bench_compare.compare_batching({"rates": {}})
    assert failures and all(
        f.startswith(bench_compare.CORRECTNESS_TAG) for f in failures)


def test_batching_gate_missing_gate_rate_fails():
    fresh = _obench({"0.8x": _rate(1000.0, 1100.0)})
    failures = bench_compare.compare_batching(fresh)
    assert any("gate rate '2.0x' not in measured rates" in f
               for f in failures)


def test_batching_gate_deadline_and_shed_load_at_gate_rate():
    failures = bench_compare.compare_batching(
        _obench({"2.0x": _rate(1000.0, 2000.0, p99=2500.0)}))
    assert any("exceeds the 2000ms request deadline" in f
               for f in failures)
    failures = bench_compare.compare_batching(
        _obench({"2.0x": _rate(1000.0, 2000.0, expired=3, ok=197)}))
    assert any("3 requests expired" in f for f in failures)
    failures = bench_compare.compare_batching(
        _obench({"2.0x": _rate(1000.0, 2000.0, rejected=5, ok=195)}))
    assert any("5 requests rejected" in f for f in failures)


def test_batching_cli_exit_codes(tmp_path):
    path = tmp_path / "bat.json"
    argv = ["--batching-fresh", str(path)]

    def wrap(ol):
        return {"benchmark": "concurrent_serving", "pools": {},
                "open_loop": ol}

    path.write_text(json.dumps(wrap(_obench())))
    assert bench_compare.main(argv) == 0
    path.write_text(json.dumps(
        wrap(_obench({"2.0x": _rate(1000.0, 1100.0)}))))
    assert bench_compare.main(argv) == 1          # perf: retryable
    assert bench_compare.main(
        argv + ["--batching-speedup-floor", "1.05"]) == 0
    path.write_text(json.dumps(
        wrap(_obench({"2.0x": _rate(1000.0, 2000.0, ok=150)}))))
    assert bench_compare.main(argv) == 2          # accounting: no retry
    path.write_text(json.dumps({"pools": {}}))    # no open_loop section
    with pytest.raises(SystemExit):
        bench_compare.main(argv)


def test_committed_batching_baseline_is_gateable():
    """The committed BENCH_concurrent.json's open_loop section must pass
    its own gate: batched >= 1.2x sequential at the gate rate, accounting
    closed, every mode oracle-validated."""
    path = pathlib.Path(__file__).resolve().parent.parent \
        / "BENCH_concurrent.json"
    data = json.loads(path.read_text()) if path.exists() else {}
    if "open_loop" not in data:
        pytest.skip("no committed open-loop baseline")
    assert bench_compare.compare_batching(data["open_loop"]) == []
