"""Unit tests: affine task-graph IR (core/taskgraph.py) + PolyBench builders."""
from __future__ import annotations

import pytest

from repro.core import polybench
from repro.core.taskgraph import (Access, Array, Statement, TaskGraph,
                                  legal_permutations)


def test_statement_rejects_unknown_iterator():
    with pytest.raises(ValueError):
        Statement("s", ("i",), {"i": 4},
                  (Access("A", ("j",)),), (Access("B", ("i",)),))


def test_reduction_loops_are_unwritten_loops():
    s = Statement("mac", ("i", "j", "k"), {"i": 2, "j": 3, "k": 4},
                  (Access("A", ("i", "k")), Access("B", ("k", "j"))),
                  (Access("C", ("i", "j")),))
    assert s.reduction_loops == ("k",)
    assert s.domain_size == 24
    assert s.flops == 48


def test_graph_rejects_unknown_array():
    with pytest.raises(ValueError):
        TaskGraph("g", {"A": Array("A", (4,))},
                  [Statement("s", ("i",), {"i": 4},
                             (Access("Zed", ("i",)),),
                             (Access("A", ("i",)),))])


def test_3mm_structure_matches_paper():
    """Paper Listing 4/5: 6 statements, E/F feed G, A-D external."""
    g = polybench.build("3mm")
    assert len(g.statements) == 6
    assert sorted(g.external_inputs()) == ["A", "B", "C", "D"]
    assert g.final_outputs() == ["G"]
    # RAW edges: E_mac -> G_mac, F_mac -> G_mac
    names = [s.name for s in g.statements]
    raw = {(names[i], names[j], a) for (i, j, a) in g.edges()}
    assert ("E_mac", "G_mac", "E") in raw
    assert ("F_mac", "G_mac", "F") in raw


def test_3mm_flops_match_closed_form():
    g = polybench.build("3mm")
    NI, NJ, NK, NL, NM = 180, 190, 200, 210, 220
    expect = 2 * (NI * NJ * NK + NJ * NL * NM + NI * NL * NJ)
    assert g.total_flops() == expect


@pytest.mark.parametrize("name", sorted(polybench.BUILDERS))
def test_every_builder_is_well_formed(name):
    g = polybench.build(name)
    assert g.statements, name
    assert g.external_inputs(), name
    assert g.final_outputs(), name
    assert g.total_flops() > 0
    # every edge references a valid statement pair in program order
    for (i, j, arr) in g.edges():
        assert 0 <= i < j < len(g.statements)
        assert arr in g.arrays


def test_io_bytes_counts_inputs_and_outputs_once():
    g = polybench.build("gemm")
    NI, NJ, NK = 200, 220, 240
    expect = 4 * (NI * NK + NK * NJ + NI * NJ)
    assert g.io_bytes() == expect


def test_legal_permutations_pin_reductions_innermost():
    g = polybench.build("gemm")
    mac = next(s for s in g.statements if s.name.endswith("mac"))
    perms = legal_permutations(mac)
    # 2 non-reduction loops -> 2 permutations, k always last
    assert len(perms) == 2
    for p in perms:
        assert p[-1] == "k0"
    assert {p[:2] for p in perms} == {("i0", "j0"), ("j0", "i0")}


def test_paper_table5_comm_between_tasks():
    """Table 5: 3mm moves 2*N^2 elements between tasks, bicg moves 0,
    atax moves N (tmp vector)."""
    from repro.core.fusion import fuse
    g3 = fuse(polybench.build("3mm"))
    # E (180x190) + F (190x210) flow between fused tasks
    assert g3.comm_between_tasks_elems() == 180 * 190 + 190 * 210
    gb = fuse(polybench.build("bicg"))
    assert gb.comm_between_tasks_elems() == 0
    ga = fuse(polybench.build("atax"))
    assert ga.comm_between_tasks_elems() == 390  # tmp (M,)
