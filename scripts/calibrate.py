"""Calibrate the cost model on this host and emit CALIBRATION.json.

Runs the microbenchmark suite (``repro.calibrate``), caches the profile
under ``REPRO_CALIBRATION_DIR``, then answers the question calibration
exists for: does the solver, fed measured rates instead of the static TPU
constants, spread 3mm's two independent matmuls across slices so the wave
schedule's width-2 wave actually runs concurrently?

The report records, side by side:

* the measured profile vs the static constants (dispatch, ICI/HBM
  bandwidth, share curve, contraction GFLOP/s);
* the 3mm slice assignment + wave shape under the *static* board and under
  the *calibrated* board;
* the decision economics: the dispatch+serialization saving of splitting
  the width-2 wave vs the cross-slice stream cost — whichever way the
  assignment lands, the numbers that justify it are in the report.

``--profile-only`` stops after the profile is measured (or loaded from
the cache): no solver report, no output file.  CI uses it to warm the
cross-run calibration cache cheaply.

Usage:
    PYTHONPATH=src python scripts/calibrate.py --out CALIBRATION.json \
        [--force] [--quick] [--profile-only] [--kernel 3mm] \
        [--budget 10] [--scale 1]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys

from repro.calibrate import calibrate
from repro.codegen import wave_schedule
from repro.core import SolverOptions, THREE_SLICE, solve
from repro.core.fusion import fuse
from repro.core.costmodel import topo_waves
from repro.core.resources import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.core.solver import TaskChoice, _evaluate, build_graph


def plan_section(graph, plan, hw, opts) -> dict:
    """Slice assignment + wave shape + split economics for one solve.

    The economics compare the *full model* both ways: the widest wave's
    tasks forced onto distinct slices vs forced co-located (same per-task
    configs, edges re-routed per assignment) — so the committed report
    genuinely justifies whichever assignment the solver chose, including
    the per-wave HBM-share de-rating a naive dispatch-vs-stream comparison
    misses.
    """
    fg = fuse(graph)
    sched = wave_schedule(fg, plan)
    wave_of = topo_waves(fg)
    # the widest wave: the concurrency opportunity the assignment decides on
    widest = max(range(len(sched.waves)), key=lambda w: len(sched.waves[w]))
    wave_tids = sched.waves[widest]
    wave_lat = [plan.reports[t].latency_s for t in wave_tids]
    # first-order terms: the serialized tail + dispatches splitting removes,
    # vs the bytes it pushes over ICI
    tail = sum(wave_lat) - max(wave_lat)
    saving = tail + hw.dispatch_s * (len(wave_tids) - 1)
    stream_bytes = sum(
        graph.arrays[a].bytes for (u, v, a) in fg.edges if u in wave_tids
    )
    # full-model comparison: re-evaluate the same per-task configs under a
    # forced-split and a forced-colocated assignment of the widest wave
    choice = {
        tid: TaskChoice(
            dataclasses.replace(cfg, slice_id=0), plan.reports[tid]
        )
        for tid, cfg in plan.configs.items()
    }
    base = {tid: cfg.slice_id for tid, cfg in plan.configs.items()}
    split = dict(base)
    for i, tid in enumerate(wave_tids):
        split[tid] = i % hw.n_slices
    coloc = dict(base)
    for tid in wave_tids:
        coloc[tid] = coloc[wave_tids[0]]
    lat_split, _, _ = _evaluate(fg, choice, split, hw, opts)
    lat_coloc, _, _ = _evaluate(fg, choice, coloc, hw, opts)
    distinct = len({sched.slice_of[t] for t in wave_tids}) > 1
    return {
        "slice_assignment": {
            str(t): c.slice_id for t, c in sorted(plan.configs.items())
        },
        "wave_slice_counts": list(sched.wave_slice_counts),
        "max_wave_width": sched.max_width,
        "distinct_slices_in_widest_wave": distinct,
        "widest_wave": [int(t) for t in wave_tids],
        "wave_of": {str(t): w for t, w in sorted(wave_of.items())},
        "model_latency_s": plan.latency_s,
        "split_economics": {
            "dispatch_plus_serialization_saving_s": saving,
            "stream_cost_s": stream_bytes / hw.ici_bw,
            "stream_bytes": stream_bytes,
            "hbm_share_at_wave_width": hw.bw_share_at(len(wave_tids)),
            "forced_split_latency_s": lat_split,
            "colocated_latency_s": lat_coloc,
            "split_pays": lat_split < lat_coloc,
        },
    }


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="CALIBRATION.json")
    ap.add_argument(
        "--force",
        action="store_true",
        help="re-measure even with a cached profile",
    )
    ap.add_argument(
        "--quick",
        action="store_true",
        help="smaller microbenchmarks (smoke)",
    )
    ap.add_argument(
        "--profile-only",
        action="store_true",
        help="measure/load the profile and stop: no solver report, no "
        "output file (CI calibration-cache warmer)",
    )
    ap.add_argument("--kernel", default="3mm")
    ap.add_argument("--scale", type=int, default=1)
    ap.add_argument("--budget", type=float, default=10.0)
    ap.add_argument("--n-slices", type=int, default=3)
    args = ap.parse_args(argv)

    profile = calibrate(force=args.force, quick=args.quick)
    print(
        f"profile: dispatch={profile.dispatch_s * 1e6:.1f}us "
        f"ici={profile.ici_bw / 1e9:.2f}GB/s "
        f"hbm={profile.hbm_bw / 1e9:.2f}GB/s "
        f"share={[round(s, 2) for s in profile.hbm_share]} "
        f"gflops={ {k: round(v, 1) for k, v in profile.gflops.items()} }"
    )
    if args.profile_only:
        return 0
    hw = profile.hardware(n_slices=args.n_slices)
    g = build_graph(args.kernel, args.scale)
    opts = SolverOptions(time_budget_s=args.budget)
    plan_static = solve(g, THREE_SLICE, opts)
    plan_cal = solve(g, hw, opts)
    static_section = plan_section(g, plan_static, THREE_SLICE, opts)
    cal_section = plan_section(g, plan_cal, hw, opts)

    report = {
        "profile": profile.to_jsonable(),
        "static_vs_measured": {
            "dispatch_s": {"static": 0.0, "measured": profile.dispatch_s},
            "ici_bw": {"static": ICI_BW, "measured": profile.ici_bw},
            "hbm_bw": {"static": HBM_BW, "measured": profile.hbm_bw},
            "peak_flops": {
                "static": PEAK_FLOPS_BF16,
                "measured": profile.peak_flops,
            },
            "hbm_share": {
                "static": "1/k",
                "measured": list(profile.hbm_share),
            },
        },
        "kernel": args.kernel,
        "scale": args.scale,
        "static": static_section,
        "calibrated": cal_section,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")

    cal = report["calibrated"]
    eco = cal["split_economics"]
    print(
        f"{args.kernel} static    : slices="
        f"{report['static']['slice_assignment']} "
        f"wave_slices={report['static']['wave_slice_counts']}"
    )
    print(
        f"{args.kernel} calibrated: slices={cal['slice_assignment']} "
        f"wave_slices={cal['wave_slice_counts']}"
    )
    print(
        f"split economics: saving="
        f"{eco['dispatch_plus_serialization_saving_s'] * 1e6:.1f}us "
        f"stream={eco['stream_cost_s'] * 1e6:.1f}us "
        f"share@width={eco['hbm_share_at_wave_width']:.2f} | "
        f"model split={eco['forced_split_latency_s'] * 1e6:.1f}us "
        f"vs coloc={eco['colocated_latency_s'] * 1e6:.1f}us "
        f"-> split_pays={eco['split_pays']} "
        f"distinct_slices={cal['distinct_slices_in_widest_wave']}"
    )
    print(f"-> {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
