"""CI benchmark-regression gate: compare a fresh BENCH_codegen.json
against the committed baseline and fail on regression.

Checks, in order:

* every kernel present in BOTH files must have ``validated: true`` in the
  fresh run (a miscompiled kernel is an instant failure, whatever its
  speed);
* no common kernel's ``speedup`` (program mode over per-task mode, a
  same-host same-run ratio, robust to absolute machine speed) may regress
  more than ``--max-kernel-regress`` (default 10%) below the baseline;
* the geometric-mean speedup over common kernels may not regress more than
  ``--max-gmean-regress`` (default 15%);
* optional absolute floors (``--floor gemver=0.9``) pin individual kernels
  to a minimum speedup independent of the baseline — the gemver serving
  regression stays fixed because CI refuses to merge anything below 0.9x.

The gmean is recomputed over the common-kernel intersection so adding or
removing a benchmark kernel does not masquerade as a perf change.

Usage:
    python scripts/bench_compare.py BASELINE.json FRESH.json \
        --max-kernel-regress 0.10 --max-gmean-regress 0.15 \
        --floor gemver=0.9
"""

from __future__ import annotations

import argparse
import json
import math
import sys


def load(path: str) -> dict:
    with open(path) as f:
        data = json.load(f)
    if "kernels" not in data:
        raise SystemExit(f"{path}: not a BENCH_codegen.json (no 'kernels')")
    return data


def gmean(values: list[float]) -> float:
    vals = [v for v in values if v > 0]
    if not vals:
        return 0.0
    return math.exp(sum(math.log(v) for v in vals) / len(vals))


def parse_floor(spec: str) -> tuple[str, float]:
    name, _, value = spec.partition("=")
    if not value:
        raise argparse.ArgumentTypeError(
            f"floor {spec!r} is not of the form kernel=value"
        )
    return name, float(value)


def compare(
    baseline: dict,
    fresh: dict,
    *,
    max_kernel_regress: float = 0.10,
    max_gmean_regress: float = 0.15,
    floors: dict[str, float] | None = None,
) -> list[str]:
    """Return the list of failure messages (empty = gate passes)."""
    failures: list[str] = []
    base_kernels = baseline["kernels"]
    fresh_kernels = fresh["kernels"]
    common = sorted(set(base_kernels) & set(fresh_kernels))
    if not common:
        return ["no common kernels between baseline and fresh run"]
    missing = sorted(set(base_kernels) - set(fresh_kernels))
    if missing:
        failures.append(f"kernels missing from fresh run: {missing}")

    for name in common:
        entry = fresh_kernels[name]
        if not entry.get("validated", False):
            failures.append(f"{name}: validated=false in fresh run")

    for name in common:
        base_s = float(base_kernels[name]["speedup"])
        new_s = float(fresh_kernels[name]["speedup"])
        if base_s > 0 and new_s < base_s * (1.0 - max_kernel_regress):
            failures.append(
                f"{name}: speedup regressed {base_s:.3f}x -> {new_s:.3f}x "
                f"(> {max_kernel_regress:.0%} below baseline)"
            )

    base_g = gmean([float(base_kernels[n]["speedup"]) for n in common])
    new_g = gmean([float(fresh_kernels[n]["speedup"]) for n in common])
    if base_g > 0 and new_g < base_g * (1.0 - max_gmean_regress):
        failures.append(
            f"gmean speedup regressed {base_g:.3f}x -> {new_g:.3f}x "
            f"(> {max_gmean_regress:.0%} below baseline)"
        )

    for name, floor in (floors or {}).items():
        entry = fresh_kernels.get(name)
        if entry is None:
            failures.append(f"{name}: floor set but kernel not benchmarked")
        elif float(entry["speedup"]) < floor:
            failures.append(
                f"{name}: speedup {float(entry['speedup']):.3f}x below "
                f"floor {floor:.3f}x"
            )
    return failures


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline", help="committed BENCH_codegen.json")
    ap.add_argument("fresh", help="freshly measured BENCH_codegen.json")
    ap.add_argument("--max-kernel-regress", type=float, default=0.10)
    ap.add_argument("--max-gmean-regress", type=float, default=0.15)
    ap.add_argument(
        "--floor",
        type=parse_floor,
        action="append",
        default=[],
        metavar="KERNEL=SPEEDUP",
        help="absolute per-kernel speedup floor (repeatable)",
    )
    args = ap.parse_args(argv)

    baseline = load(args.baseline)
    fresh = load(args.fresh)
    common = sorted(set(baseline["kernels"]) & set(fresh["kernels"]))
    for name in common:
        base_s = float(baseline["kernels"][name]["speedup"])
        new_s = float(fresh["kernels"][name]["speedup"])
        delta = (new_s / base_s - 1.0) * 100 if base_s else float("nan")
        print(
            f"{name:10s} baseline={base_s:6.3f}x fresh={new_s:6.3f}x "
            f"({delta:+.1f}%) validated="
            f"{fresh['kernels'][name].get('validated')}"
        )

    failures = compare(
        baseline,
        fresh,
        max_kernel_regress=args.max_kernel_regress,
        max_gmean_regress=args.max_gmean_regress,
        floors=dict(args.floor),
    )
    if failures:
        print("\nBENCH GATE FAILED:", file=sys.stderr)
        for msg in failures:
            print(f"  - {msg}", file=sys.stderr)
        return 1
    print("\nbench gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
