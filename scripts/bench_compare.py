"""CI benchmark-regression gate: compare fresh benchmark JSONs against the
committed baselines and fail on regression.

Per-kernel gate (BENCH_codegen.json), checks in order:

* every kernel present in BOTH files must have ``validated: true`` in the
  fresh run (a miscompiled kernel is an instant failure, whatever its
  speed);
* no common kernel's ``speedup`` (program mode over per-task mode, a
  same-host same-run ratio, robust to absolute machine speed) may regress
  more than ``--max-kernel-regress`` (default 10%) below the baseline;
* the geometric-mean speedup over common kernels may not regress more than
  ``--max-gmean-regress`` (default 15%);
* optional absolute floors (``--floor gemver=0.9``) pin individual kernels
  to a minimum speedup independent of the baseline — the gemver serving
  regression stays fixed because CI refuses to merge anything below 0.9x.

The gmean is recomputed over the common-kernel intersection so adding or
removing a benchmark kernel does not masquerade as a perf change.

Concurrent-serving gate (BENCH_concurrent.json, via
``--concurrent-baseline``/``--concurrent-fresh``):

* every fresh pool section must be ``validated`` with zero
  ``lost_updates`` and no worker errors (the thread-safety stress signal);
* no common pool size's ``scaling_vs_first`` (throughput relative to the
  run's first pool size — a same-run ratio, robust to absolute runner
  speed) may regress more than ``--max-concurrent-regress`` (default 15%)
  below the baseline.

Frontend gate (BENCH_frontend.json, via
``--frontend-baseline``/``--frontend-fresh``):

* every fresh workload must be ``validated`` against the ``jax.jit``
  oracle (a mis-traced program is a correctness failure, never retried);
* the coverage fractions (``coverage_eqns``/``coverage_flops``) may not
  drop below the baseline — the lowering is deterministic, so any drop is
  a lowering regression, also tagged correctness;
* the fresh run's ``ratio`` fields (jit seconds over traced-program
  seconds — a same-run paired median, robust to runner speed) gate
  against HARD floors, not a baseline-relative band: the gmean over all
  fresh workloads must be ≥ ``--frontend-gmean-floor`` (default 1.0 —
  the traced program may never lose to plain ``jax.jit`` overall) and
  every workload must be ≥ ``--frontend-workload-floor`` (default 0.95 —
  one workload may sit inside the noise band, but not lose outright).

Batching gate (the ``open_loop`` section of BENCH_concurrent.json, via
``--batching-fresh`` — fresh-run-only, absolute floors, no baseline):

* request accounting must balance in every mode at every offered rate:
  ``ok + fallbacks + expired + rejected + errors == issued`` — a request
  the batcher lost is a correctness failure, never retried;
* no mode may report request errors, and every mode's post-run response
  must be ``validated`` against the ``jax.jit`` oracle (both
  correctness-tagged);
* at the gate rate (the overloaded offered load, ``gate_rate`` in the
  file), batched throughput must be at least
  ``--batching-speedup-floor`` (default 1.2) times sequential throughput
  — a same-run same-schedule ratio, robust to absolute runner speed;
* at the gate rate the batched p99 latency must stay within the request
  deadline, and no request may have expired or been rejected — the
  batcher must absorb the overload, not shed it.

Chaos gate (BENCH_chaos.json, via ``--chaos-fresh`` — fresh-run-only,
absolute floors, no baseline file):

* availability (fraction of submits answered correctly, wrong values and
  dropped requests both counting against it) must stay at or above
  ``--chaos-availability-floor`` (default 0.99) in both the clean and the
  fault-injected scenario — in the faulted run that is the resilience
  contract itself, so a miss is correctness-tagged and never retried;
* the faulted scenario must actually have injected faults, the entry's
  breaker must have closed again after background re-solve, and the
  corrupted-artifact round trip (quarantine + regenerate) must survive.

Solver gate (BENCH_solver.json, via ``--solver-fresh`` — fresh-run-only,
absolute floors, no baseline):

* the parallel sweep must beat the serial sweep by at least
  ``--solver-speedup-floor`` (default 1.43x, i.e. parallel wall time at
  most 0.7x serial) on the largest benchmarked graph — a same-run
  same-seed ratio, robust to absolute runner speed;
* the parallel plan's modeled latency may not be worse than the serial
  plan's on the same seed — the pruning bound is provably conservative,
  so a worse plan means the sweep lost a winning candidate
  (correctness-tagged, never retried);
* a warm plan-store solve must be a hit with **zero** solver evaluations
  and the same plan fingerprint (correctness-tagged), completing within
  ``--solver-warm-ms`` (default 50 ms);
* a warm engine ``register_function`` against the same store must also
  hit with zero evaluations (correctness-tagged).

Observability gate (BENCH_obs.json, via ``--obs-fresh`` —
fresh-run-only, absolute floors, no baseline):

* the hot-path overhead of span tracing + registry-backed metrics
  (``overhead.overhead_ratio``, a per-call-paired same-run median,
  robust to runner speed) must stay at or below
  ``--obs-overhead-ceiling`` (default 1.03, the 3% p50 budget) —
  a perf number on a shared runner, so retryable;
* the drift detector must have fired on the deliberately miscalibrated
  profile AND driven the background plan refresh to completion, with no
  accounting invariant violated (correctness-tagged — a dead feedback
  loop or broken closure is never retried);
* the Prometheus text exposition and the Chrome-trace export must both
  validate structurally (correctness-tagged).

Usage:
    python scripts/bench_compare.py BASELINE.json FRESH.json \
        --max-kernel-regress 0.10 --max-gmean-regress 0.15 \
        --floor gemver=0.9 \
        --concurrent-baseline BENCH_concurrent.json \
        --concurrent-fresh BENCH_concurrent_fresh.json \
        --frontend-baseline BENCH_frontend.json \
        --frontend-fresh BENCH_frontend_fresh.json \
        --frontend-gmean-floor 1.0 --frontend-workload-floor 0.95 \
        --batching-fresh BENCH_concurrent_fresh.json \
        --batching-speedup-floor 1.2
"""

from __future__ import annotations

import argparse
import json
import math
import sys


def load(path: str) -> dict:
    with open(path) as f:
        data = json.load(f)
    if "kernels" not in data:
        raise SystemExit(f"{path}: not a BENCH_codegen.json (no 'kernels')")
    return data


def load_concurrent(path: str) -> dict:
    with open(path) as f:
        data = json.load(f)
    if "pools" not in data:
        raise SystemExit(f"{path}: not a BENCH_concurrent.json (no 'pools')")
    return data


def load_open_loop(path: str) -> dict:
    with open(path) as f:
        data = json.load(f)
    if "open_loop" not in data:
        raise SystemExit(
            f"{path}: no 'open_loop' section (run bench_concurrent with "
            f"--open-loop-requests)"
        )
    return data["open_loop"]


def load_chaos(path: str) -> dict:
    with open(path) as f:
        data = json.load(f)
    if "scenarios" not in data:
        raise SystemExit(
            f"{path}: not a BENCH_chaos.json (no 'scenarios')"
        )
    return data


def load_solver(path: str) -> dict:
    with open(path) as f:
        data = json.load(f)
    if data.get("benchmark") != "solver_parallel_store":
        raise SystemExit(
            f"{path}: not a BENCH_solver.json "
            f"(benchmark={data.get('benchmark')!r})"
        )
    return data


def load_obs(path: str) -> dict:
    with open(path) as f:
        data = json.load(f)
    if data.get("benchmark") != "obs":
        raise SystemExit(
            f"{path}: not a BENCH_obs.json "
            f"(benchmark={data.get('benchmark')!r})"
        )
    return data


def load_frontend(path: str) -> dict:
    with open(path) as f:
        data = json.load(f)
    if "workloads" not in data:
        raise SystemExit(
            f"{path}: not a BENCH_frontend.json (no 'workloads')"
        )
    return data


def gmean(values: list[float]) -> float:
    vals = [v for v in values if v > 0]
    if not vals:
        return 0.0
    return math.exp(sum(math.log(v) for v in vals) / len(vals))


def parse_floor(spec: str) -> tuple[str, float]:
    name, _, value = spec.partition("=")
    if not value:
        raise argparse.ArgumentTypeError(
            f"floor {spec!r} is not of the form kernel=value"
        )
    return name, float(value)


def compare(
    baseline: dict,
    fresh: dict,
    *,
    max_kernel_regress: float = 0.10,
    max_gmean_regress: float = 0.15,
    floors: dict[str, float] | None = None,
) -> list[str]:
    """Return the list of failure messages (empty = gate passes)."""
    failures: list[str] = []
    base_kernels = baseline["kernels"]
    fresh_kernels = fresh["kernels"]
    common = sorted(set(base_kernels) & set(fresh_kernels))
    if not common:
        return ["no common kernels between baseline and fresh run"]
    missing = sorted(set(base_kernels) - set(fresh_kernels))
    if missing:
        failures.append(f"kernels missing from fresh run: {missing}")

    for name in common:
        entry = fresh_kernels[name]
        if not entry.get("validated", False):
            failures.append(f"{name}: validated=false in fresh run")

    for name in common:
        base_s = float(base_kernels[name]["speedup"])
        new_s = float(fresh_kernels[name]["speedup"])
        if base_s > 0 and new_s < base_s * (1.0 - max_kernel_regress):
            failures.append(
                f"{name}: speedup regressed {base_s:.3f}x -> {new_s:.3f}x "
                f"(> {max_kernel_regress:.0%} below baseline)"
            )

    base_g = gmean([float(base_kernels[n]["speedup"]) for n in common])
    new_g = gmean([float(fresh_kernels[n]["speedup"]) for n in common])
    if base_g > 0 and new_g < base_g * (1.0 - max_gmean_regress):
        failures.append(
            f"gmean speedup regressed {base_g:.3f}x -> {new_g:.3f}x "
            f"(> {max_gmean_regress:.0%} below baseline)"
        )

    for name, floor in (floors or {}).items():
        entry = fresh_kernels.get(name)
        if entry is None:
            failures.append(f"{name}: floor set but kernel not benchmarked")
        elif float(entry["speedup"]) < floor:
            failures.append(
                f"{name}: speedup {float(entry['speedup']):.3f}x below "
                f"floor {floor:.3f}x"
            )
    return failures


#: Prefix marking failures that must NEVER be retried away by CI (an
#: intermittent thread-safety failure is a bug, not noise).  ``main``
#: returns exit code 2 when any failure carries it — the machine-readable
#: contract the workflow's retry logic branches on.
CORRECTNESS_TAG = "[correctness]"


def compare_concurrent(
    baseline: dict,
    fresh: dict,
    *,
    max_regress: float = 0.15,
) -> list[str]:
    """Concurrent-serving gate; returns failure messages (empty = pass).

    Throughput in req/s is runner-dependent, so the regression check runs
    on ``scaling_vs_first`` — each pool size's throughput relative to the
    same run's first pool — which cancels absolute machine speed the same
    way the kernel gate's speedup ratios do.  The correctness fields
    (``validated``/``lost_updates``/``errors``) gate absolutely: a racy
    serving layer fails whatever its speed.
    """
    failures: list[str] = []
    base_pools = baseline["pools"]
    fresh_pools = fresh["pools"]
    for k in sorted(fresh_pools, key=int):
        entry = fresh_pools[k]
        if not entry.get("validated", False):
            failures.append(
                f"{CORRECTNESS_TAG} pool {k}: validated=false in fresh run"
            )
        if entry.get("lost_updates", 0):
            failures.append(
                f"{CORRECTNESS_TAG} pool {k}: "
                f"{entry['lost_updates']} lost updates "
                f"(thread-safety stress failed)"
            )
        if entry.get("errors"):
            failures.append(
                f"{CORRECTNESS_TAG} pool {k}: worker errors "
                f"{entry['errors'][:2]}"
            )
        if float(entry.get("throughput_rps", 0.0)) <= 0.0:
            failures.append(f"pool {k}: zero throughput")
    common = sorted(set(base_pools) & set(fresh_pools), key=int)
    if not common:
        failures.append("no common pool sizes between baseline and fresh")
    # scaling_vs_first is normalized against each run's OWN first pool;
    # comparing ratios with different denominators would be meaningless
    base_norm = baseline.get("scaling_baseline_pool")
    fresh_norm = fresh.get("scaling_baseline_pool")
    if None not in (base_norm, fresh_norm) and base_norm != fresh_norm:
        failures.append(
            f"scaling normalized against different pools "
            f"(baseline pool {base_norm}, fresh pool {fresh_norm})"
        )
        return failures
    for k in common:
        base_s = float(base_pools[k].get("scaling_vs_first", 0.0))
        new_s = float(fresh_pools[k].get("scaling_vs_first", 0.0))
        if base_s > 0 and new_s < base_s * (1.0 - max_regress):
            failures.append(
                f"pool {k}: concurrent scaling regressed "
                f"{base_s:.3f}x -> {new_s:.3f}x "
                f"(> {max_regress:.0%} below baseline)"
            )
    return failures


def compare_frontend(
    baseline: dict,
    fresh: dict,
    *,
    gmean_floor: float = 1.0,
    workload_floor: float = 0.95,
) -> list[str]:
    """Frontend trace gate; returns failure messages (empty = pass).

    Validation and coverage gate absolutely (both are deterministic: a
    traced program that stops matching the ``jax.jit`` oracle, or a
    lowering that suddenly owns fewer equations, is a code regression, not
    runner noise — tagged so CI never retries them).  The timing gate is a
    HARD floor, not a baseline-relative band: the fresh run's gmean
    ``ratio`` (jit seconds over traced-program seconds, a same-run paired
    median so absolute machine speed cancels) must stay at or above
    ``gmean_floor`` (default 1.0 — the traced program may never lose to
    plain ``jax.jit``), and no single workload may fall below
    ``workload_floor`` (default 0.95 — one workload may sit in the noise
    band, but not lose outright).
    """
    failures: list[str] = []
    base_w = baseline["workloads"]
    fresh_w = fresh["workloads"]
    for name in sorted(fresh_w):
        if not fresh_w[name].get("validated", False):
            failures.append(
                f"{CORRECTNESS_TAG} {name}: traced program failed "
                f"jax.jit-oracle validation"
            )
    common = sorted(set(base_w) & set(fresh_w))
    if not common:
        failures.append("no common frontend workloads")
        return failures
    missing = sorted(set(base_w) - set(fresh_w))
    if missing:
        failures.append(
            f"frontend workloads missing from fresh run: {missing}"
        )
    for name in common:
        for field in ("coverage_eqns", "coverage_flops"):
            base_c = float(base_w[name].get(field, 0.0))
            new_c = float(fresh_w[name].get(field, 0.0))
            if new_c < base_c - 1e-9:
                failures.append(
                    f"{CORRECTNESS_TAG} {name}: {field} dropped "
                    f"{base_c:.4f} -> {new_c:.4f} (lowering regression)"
                )
    for name in sorted(fresh_w):
        new_r = float(fresh_w[name].get("ratio", 0.0))
        if new_r < workload_floor:
            failures.append(
                f"{name}: jit/program ratio {new_r:.3f}x below the "
                f"{workload_floor:.2f}x per-workload floor"
            )
    fresh_g = gmean([float(fresh_w[n].get("ratio", 0.0))
                     for n in sorted(fresh_w)])
    if fresh_g < gmean_floor:
        failures.append(
            f"gmean jit/program ratio {fresh_g:.3f}x below the "
            f"{gmean_floor:.2f}x floor — the traced program must not "
            f"lose to plain jax.jit"
        )
    return failures


def compare_batching(
    fresh: dict,
    *,
    speedup_floor: float = 1.2,
) -> list[str]:
    """Continuous-batching gate (the ``open_loop`` section); fresh-run
    absolute floors, no baseline file.

    The throughput check is a same-run ratio — batched vs sequential
    serving of the *same* deterministic arrival schedule on the same
    runner — so absolute machine speed cancels, like every other ratio
    gate here.  The accounting invariant (every issued request ends in
    exactly one of ok/fallbacks/expired/rejected/errors) and the oracle
    validation are correctness checks CI must never retry away.
    """
    failures: list[str] = []
    rates = fresh.get("rates", {})
    if not rates:
        return [f"{CORRECTNESS_TAG} batching: no offered rates measured"]
    for rk in sorted(rates):
        r = rates[rk]
        for mode in ("sequential", "batched"):
            m = r.get(mode)
            if m is None:
                failures.append(
                    f"{CORRECTNESS_TAG} batching/{rk}: mode {mode!r} "
                    f"missing"
                )
                continue
            accounted = sum(
                int(m.get(k, 0))
                for k in ("ok", "fallbacks", "expired", "rejected",
                          "errors")
            )
            if accounted != int(m.get("issued", -1)):
                failures.append(
                    f"{CORRECTNESS_TAG} batching/{rk}/{mode}: request "
                    f"accounting broken — ok+fallbacks+expired+rejected+"
                    f"errors = {accounted}, issued = {m.get('issued')}"
                )
            if int(m.get("errors", 0)):
                failures.append(
                    f"{CORRECTNESS_TAG} batching/{rk}/{mode}: "
                    f"{m['errors']} request errors"
                )
            if not m.get("validated", False):
                failures.append(
                    f"{CORRECTNESS_TAG} batching/{rk}/{mode}: post-run "
                    f"response failed jax.jit-oracle validation"
                )
    gate_rate = fresh.get("gate_rate")
    gate = rates.get(gate_rate)
    if gate is None:
        failures.append(
            f"batching: gate rate {gate_rate!r} not in measured rates "
            f"{sorted(rates)}"
        )
        return failures
    ratio = float(gate.get("batched_vs_sequential", 0.0))
    if ratio < speedup_floor:
        failures.append(
            f"batching/{gate_rate}: batched throughput only {ratio:.2f}x "
            f"sequential, below the {speedup_floor:.2f}x floor "
            f"(batched {gate.get('batched', {}).get('throughput_rps')} "
            f"vs sequential "
            f"{gate.get('sequential', {}).get('throughput_rps')} req/s)"
        )
    batched = gate.get("batched", {})
    deadline_ms = float(fresh.get("deadline_ms", 0.0))
    p99 = float(batched.get("p99_ms", 0.0))
    if deadline_ms and p99 > deadline_ms:
        failures.append(
            f"batching/{gate_rate}: batched p99 {p99:.1f}ms exceeds the "
            f"{deadline_ms:.0f}ms request deadline"
        )
    for k in ("expired", "rejected"):
        if int(batched.get(k, 0)):
            failures.append(
                f"batching/{gate_rate}: {batched[k]} requests {k} — the "
                f"batcher shed load it should have absorbed"
            )
    return failures


def compare_solver(
    fresh: dict,
    *,
    speedup_floor: float = 1.43,
    warm_ms: float = 50.0,
) -> list[str]:
    """Parallel-sweep + plan-store gate (BENCH_solver.json); fresh-run
    absolute floors, no baseline file.

    The speedup check is a same-run same-seed ratio (serial vs parallel
    wall time of the *same* solve on the same runner), so absolute
    machine speed cancels.  The plan-quality and store-hit checks are
    deterministic properties of the code — the pruning bound is
    conservative by construction and a store hit replays a serialized
    plan — so their failures are correctness-tagged and never retried.
    """
    failures: list[str] = []
    serial = fresh.get("serial", {})
    parallel = fresh.get("parallel", {})
    warm = fresh.get("warm", {})
    engine = fresh.get("engine", {})

    if serial.get("timed_out"):
        failures.append(
            "solver: the serial solve hit its time budget — the speedup "
            "ratio is meaningless; raise --budget"
        )
    speedup = float(fresh.get("speedup", 0.0))
    if speedup < speedup_floor:
        failures.append(
            f"solver: parallel sweep only {speedup:.2f}x faster than "
            f"serial, below the {speedup_floor:.2f}x floor "
            f"(serial {serial.get('solver_s', 0):.2f}s vs parallel "
            f"{parallel.get('solver_s', 0):.2f}s, "
            f"workers={fresh.get('workers')})"
        )
    ser_lat = float(serial.get("latency_s", 0.0))
    par_lat = float(parallel.get("latency_s", 0.0))
    if ser_lat > 0 and par_lat > ser_lat * (1.0 + 1e-9):
        failures.append(
            f"{CORRECTNESS_TAG} solver: parallel plan latency "
            f"{par_lat:.3e}s is WORSE than serial {ser_lat:.3e}s on the "
            f"same seed — the pruned sweep lost a winning candidate"
        )

    if not warm.get("store_hit", False):
        failures.append(
            f"{CORRECTNESS_TAG} solver: warm solve was not a plan-store "
            f"hit"
        )
    if int(warm.get("n_evaluated", -1)) != 0:
        failures.append(
            f"{CORRECTNESS_TAG} solver: warm store hit ran "
            f"{warm.get('n_evaluated')} sweep evaluations (must be 0)"
        )
    if warm.get("plan_fp") != parallel.get("plan_fp"):
        failures.append(
            f"{CORRECTNESS_TAG} solver: warm plan fingerprint "
            f"{warm.get('plan_fp')!r} != stored plan "
            f"{parallel.get('plan_fp')!r} — the store round trip changed "
            f"the plan"
        )
    warm_s = float(warm.get("solver_s", float("inf")))
    if warm_s * 1e3 > warm_ms:
        failures.append(
            f"solver: warm store hit took {warm_s * 1e3:.1f}ms, above "
            f"the {warm_ms:.0f}ms budget"
        )

    if not engine.get("warm_store_hit", False):
        failures.append(
            f"{CORRECTNESS_TAG} solver: warm engine register_function "
            f"was not a plan-store hit"
        )
    if int(engine.get("warm_evals", -1)) != 0:
        failures.append(
            f"{CORRECTNESS_TAG} solver: warm engine register_function "
            f"ran {engine.get('warm_evals')} sweep evaluations "
            f"(must be 0)"
        )
    return failures


def compare_chaos(
    fresh: dict,
    *,
    availability_floor: float = 0.99,
) -> list[str]:
    """Chaos-serving gate (BENCH_chaos.json); fresh-run absolute floors.

    There is no baseline file: the resilience contract is absolute, not
    relative.  Availability (fraction of submits answered *correctly* —
    a wrong value counts against it the same as a dropped request) must
    stay at or above ``availability_floor`` in BOTH scenarios; in the
    faulted scenario that means every injected fault was absorbed by the
    fallback path, so a miss is a correctness failure CI must never retry
    away.  The breaker must have closed again after background re-solve,
    and the corrupted-artifact round trip (quarantine + regenerate) must
    have survived.
    """
    failures: list[str] = []
    scenarios = fresh.get("scenarios", {})
    for label in ("clean", "faulted"):
        s = scenarios.get(label)
        if s is None:
            failures.append(
                f"{CORRECTNESS_TAG} chaos: scenario {label!r} missing"
            )
            continue
        avail = float(s.get("availability", 0.0))
        if avail < availability_floor:
            failures.append(
                f"{CORRECTNESS_TAG} chaos/{label}: availability "
                f"{avail:.4f} below the {availability_floor:.2f} floor "
                f"({s.get('correct')}/{s.get('requests')} correct; "
                f"errors {s.get('errors', [])[:2]})"
            )
        if not s.get("breaker_closed_after_recovery", False):
            failures.append(
                f"chaos/{label}: breaker did not close after background "
                f"re-solve (final state {s.get('final_state')!r})"
            )
    faulted = scenarios.get("faulted", {})
    if faulted and not faulted.get("injected"):
        failures.append(
            f"{CORRECTNESS_TAG} chaos/faulted: no faults were actually "
            f"injected — the scenario measured nothing"
        )
    art = fresh.get("artifact_recovery", {})
    for field in ("survived_corrupt_load", "quarantined", "regenerated"):
        if not art.get(field, False):
            failures.append(
                f"{CORRECTNESS_TAG} chaos: artifact recovery failed "
                f"({field}=false)"
            )
    return failures


def compare_obs(fresh: dict, *, overhead_ceiling: float) -> list[str]:
    """Absolute gates on a fresh BENCH_obs.json (no baseline file)."""
    failures: list[str] = []
    ov = fresh.get("overhead", {})
    ratio = float(ov.get("overhead_ratio", float("inf")))
    if ratio > overhead_ceiling:
        failures.append(
            f"obs/overhead: tracing+metrics on costs {ratio:.4f}x vs off, "
            f"over the {overhead_ceiling:.2f} ceiling "
            f"(p50 off={ov.get('off_p50_s', 0) * 1e6:.1f}us "
            f"on={ov.get('on_p50_s', 0) * 1e6:.1f}us)"
        )
    if not ov.get("spans_recorded", 0):
        failures.append(
            f"{CORRECTNESS_TAG} obs/overhead: no spans were recorded in "
            f"the 'on' windows — the bench measured nothing"
        )
    dr = fresh.get("drift", {})
    if not dr.get("triggered", False):
        failures.append(
            f"{CORRECTNESS_TAG} obs/drift: the deliberately miscalibrated "
            f"profile did not fire the drift detector "
            f"(ratio={dr.get('ratio')})"
        )
    if not dr.get("refresh_completed", False):
        failures.append(
            f"{CORRECTNESS_TAG} obs/drift: drift fired but the background "
            f"plan refresh never completed"
        )
    if dr.get("invariant_failures"):
        failures.append(
            f"{CORRECTNESS_TAG} obs/drift: accounting invariants violated "
            f"under drift-triggered refresh: {dr['invariant_failures']}"
        )
    ex = fresh.get("export", {})
    if not ex.get("exposition_valid", False):
        failures.append(
            f"{CORRECTNESS_TAG} obs/export: Prometheus exposition invalid "
            f"({ex.get('exposition_problems', ['missing section'])[:3]})"
        )
    if not ex.get("trace_valid", False):
        failures.append(
            f"{CORRECTNESS_TAG} obs/export: Chrome-trace export invalid "
            f"({ex.get('trace_problems', ['missing section'])[:3]})"
        )
    return failures


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "baseline",
        nargs="?",
        default=None,
        help="committed BENCH_codegen.json",
    )
    ap.add_argument(
        "fresh", nargs="?", default=None, help="fresh BENCH_codegen.json"
    )
    ap.add_argument("--max-kernel-regress", type=float, default=0.10)
    ap.add_argument("--max-gmean-regress", type=float, default=0.15)
    ap.add_argument(
        "--floor",
        type=parse_floor,
        action="append",
        default=[],
        metavar="KERNEL=SPEEDUP",
        help="absolute per-kernel speedup floor (repeatable)",
    )
    ap.add_argument(
        "--concurrent-baseline",
        default=None,
        help="committed BENCH_concurrent.json",
    )
    ap.add_argument(
        "--concurrent-fresh",
        default=None,
        help="freshly measured BENCH_concurrent.json",
    )
    ap.add_argument("--max-concurrent-regress", type=float, default=0.15)
    ap.add_argument(
        "--frontend-baseline",
        default=None,
        help="committed BENCH_frontend.json",
    )
    ap.add_argument(
        "--frontend-fresh",
        default=None,
        help="freshly measured BENCH_frontend.json",
    )
    ap.add_argument("--frontend-gmean-floor", type=float, default=1.0)
    ap.add_argument("--frontend-workload-floor", type=float, default=0.95)
    ap.add_argument(
        "--chaos-fresh",
        default=None,
        help="freshly measured BENCH_chaos.json (absolute floors, "
        "no baseline)",
    )
    ap.add_argument("--chaos-availability-floor", type=float, default=0.99)
    ap.add_argument(
        "--batching-fresh",
        default=None,
        help="freshly measured BENCH_concurrent.json with an open_loop "
        "section (absolute floors, no baseline)",
    )
    ap.add_argument("--batching-speedup-floor", type=float, default=1.2)
    ap.add_argument(
        "--solver-fresh",
        default=None,
        help="freshly measured BENCH_solver.json (absolute floors, "
        "no baseline)",
    )
    ap.add_argument("--solver-speedup-floor", type=float, default=1.43)
    ap.add_argument("--solver-warm-ms", type=float, default=50.0)
    ap.add_argument(
        "--obs-fresh",
        default=None,
        help="freshly measured BENCH_obs.json (absolute floors, "
        "no baseline)",
    )
    ap.add_argument("--obs-overhead-ceiling", type=float, default=1.03)
    args = ap.parse_args(argv)

    if (args.baseline is None) != (args.fresh is None):
        ap.error("baseline and fresh must be given together")
    if (args.concurrent_baseline is None) != (args.concurrent_fresh is None):
        ap.error(
            "--concurrent-baseline and --concurrent-fresh must be "
            "given together"
        )
    if (args.frontend_baseline is None) != (args.frontend_fresh is None):
        ap.error(
            "--frontend-baseline and --frontend-fresh must be "
            "given together"
        )
    if (
        args.baseline is None
        and args.concurrent_baseline is None
        and args.frontend_baseline is None
        and args.chaos_fresh is None
        and args.batching_fresh is None
        and args.solver_fresh is None
        and args.obs_fresh is None
    ):
        ap.error(
            "nothing to compare: give BASELINE FRESH and/or "
            "--concurrent-baseline/--concurrent-fresh and/or "
            "--frontend-baseline/--frontend-fresh and/or --chaos-fresh "
            "and/or --batching-fresh and/or --solver-fresh and/or "
            "--obs-fresh"
        )

    failures: list[str] = []
    if args.baseline is not None:
        baseline = load(args.baseline)
        fresh = load(args.fresh)
        common = sorted(set(baseline["kernels"]) & set(fresh["kernels"]))
        for name in common:
            base_s = float(baseline["kernels"][name]["speedup"])
            new_s = float(fresh["kernels"][name]["speedup"])
            delta = (new_s / base_s - 1.0) * 100 if base_s else float("nan")
            print(
                f"{name:10s} baseline={base_s:6.3f}x fresh={new_s:6.3f}x "
                f"({delta:+.1f}%) validated="
                f"{fresh['kernels'][name].get('validated')}"
            )
        failures += compare(
            baseline,
            fresh,
            max_kernel_regress=args.max_kernel_regress,
            max_gmean_regress=args.max_gmean_regress,
            floors=dict(args.floor),
        )

    if args.concurrent_baseline is not None:
        cbase = load_concurrent(args.concurrent_baseline)
        cfresh = load_concurrent(args.concurrent_fresh)
        for k in sorted(cfresh["pools"], key=int):
            e = cfresh["pools"][k]
            b = cbase["pools"].get(k, {})
            print(
                f"pool={k}: {e.get('throughput_rps', 0):9.1f} req/s "
                f"scaling={e.get('scaling_vs_first', 0):5.2f}x "
                f"(baseline {b.get('scaling_vs_first', 0):5.2f}x) "
                f"lost={e.get('lost_updates')} "
                f"validated={e.get('validated')}"
            )
        failures += compare_concurrent(
            cbase, cfresh, max_regress=args.max_concurrent_regress
        )

    if args.frontend_baseline is not None:
        fbase = load_frontend(args.frontend_baseline)
        ffresh = load_frontend(args.frontend_fresh)
        for name in sorted(ffresh["workloads"]):
            e = ffresh["workloads"][name]
            b = fbase["workloads"].get(name, {})
            print(
                f"{name:12s} ratio={e.get('ratio', 0):6.3f}x "
                f"(baseline {b.get('ratio', 0):6.3f}x) "
                f"coverage={e.get('coverage_flops', 0):.4f} "
                f"(baseline {b.get('coverage_flops', 0):.4f}) "
                f"validated={e.get('validated')}"
            )
        failures += compare_frontend(
            fbase,
            ffresh,
            gmean_floor=args.frontend_gmean_floor,
            workload_floor=args.frontend_workload_floor,
        )

    if args.batching_fresh is not None:
        ol = load_open_loop(args.batching_fresh)
        print(
            f"batching: capacity={ol.get('capacity_rps', 0):.1f} req/s "
            f"max_batch={ol.get('max_batch')} "
            f"gate_rate={ol.get('gate_rate')}"
        )
        for rk in sorted(ol.get("rates", {})):
            r = ol["rates"][rk]
            s = r.get("sequential", {})
            b = r.get("batched", {})
            print(
                f"batching/{rk:6s} offered={r.get('offered_rps', 0):9.1f} "
                f"seq={s.get('throughput_rps', 0):8.1f} "
                f"bat={b.get('throughput_rps', 0):8.1f} req/s "
                f"ratio={r.get('batched_vs_sequential', 0):5.2f}x "
                f"bat_p99={b.get('p99_ms', 0):7.1f}ms "
                f"occupancy={b.get('bucket_occupancy', 0):.2f}"
            )
        failures += compare_batching(
            ol, speedup_floor=args.batching_speedup_floor
        )

    if args.solver_fresh is not None:
        sv = load_solver(args.solver_fresh)
        serial = sv.get("serial", {})
        parallel = sv.get("parallel", {})
        warm = sv.get("warm", {})
        engine = sv.get("engine", {})
        print(
            f"solver: kernel={sv.get('kernel')} "
            f"workers={sv.get('workers')} "
            f"serial={serial.get('solver_s', 0):.2f}s "
            f"parallel={parallel.get('solver_s', 0):.2f}s "
            f"speedup={sv.get('speedup', 0):.2f}x "
            f"evals={serial.get('n_evaluated')}->"
            f"{parallel.get('n_evaluated')}"
        )
        print(
            f"solver/warm: {warm.get('solver_s', 0) * 1e3:.1f}ms "
            f"hit={warm.get('store_hit')} evals={warm.get('n_evaluated')} "
            f"fp_match={warm.get('plan_fp') == parallel.get('plan_fp')}"
        )
        print(
            f"solver/engine: cold={engine.get('cold_register_s', 0):.2f}s "
            f"warm={engine.get('warm_register_s', 0) * 1e3:.1f}ms "
            f"warm_evals={engine.get('warm_evals')}"
        )
        failures += compare_solver(
            sv,
            speedup_floor=args.solver_speedup_floor,
            warm_ms=args.solver_warm_ms,
        )

    if args.chaos_fresh is not None:
        chaos = load_chaos(args.chaos_fresh)
        for label, s in sorted(chaos["scenarios"].items()):
            print(
                f"chaos/{label:8s} availability="
                f"{s.get('availability', 0):.4f} "
                f"p99={s.get('p99_ms', 0):8.2f}ms "
                f"failures={s.get('failures')} "
                f"fallbacks={s.get('fallbacks')} "
                f"state={s.get('final_state')}"
            )
        print(f"chaos/artifacts {chaos.get('artifact_recovery')}")
        failures += compare_chaos(
            chaos, availability_floor=args.chaos_availability_floor
        )

    if args.obs_fresh is not None:
        obs = load_obs(args.obs_fresh)
        ov = obs.get("overhead", {})
        dr = obs.get("drift", {})
        ex = obs.get("export", {})
        print(
            f"obs/overhead ratio={ov.get('overhead_ratio', 0):.4f} "
            f"off_p50={ov.get('off_p50_s', 0) * 1e6:.1f}us "
            f"on_p50={ov.get('on_p50_s', 0) * 1e6:.1f}us "
            f"pairs={ov.get('pairs')} "
            f"spans={ov.get('spans_recorded')}"
        )
        print(
            f"obs/drift    triggered={dr.get('triggered')} "
            f"refresh_completed={dr.get('refresh_completed')} "
            f"triggers={dr.get('triggers')} "
            f"ratio={dr.get('ratio') or 0:.3g}"
        )
        print(
            f"obs/export   exposition_valid={ex.get('exposition_valid')} "
            f"trace_valid={ex.get('trace_valid')} "
            f"spans={ex.get('n_spans')} "
            f"lines={ex.get('exposition_lines')}"
        )
        failures += compare_obs(
            obs, overhead_ceiling=args.obs_overhead_ceiling
        )

    if failures:
        print("\nBENCH GATE FAILED:", file=sys.stderr)
        for msg in failures:
            print(f"  - {msg}", file=sys.stderr)
        if any(msg.startswith(CORRECTNESS_TAG) for msg in failures):
            return 2        # correctness failure: CI must not retry
        return 1
    print("\nbench gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
