"""Insert the final roofline table into EXPERIMENTS.md from the dry-run
artifacts.  Run after the full sweep:

    PYTHONPATH=src python scripts/fill_experiments.py
"""

import json
import os
import sys

DIR = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun_final"
MD = "EXPERIMENTS.md"
MARK = "<!-- ROOFLINE_TABLE -->"


def load(mesh):
    cells = []
    for fn in sorted(os.listdir(DIR)):
        if fn.endswith(f"_{mesh}.json"):
            with open(os.path.join(DIR, fn)) as f:
                cells.append(json.load(f))
    return cells


def fmt(cells):
    lines = [
        "| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | bound | "
        "useful | roofline | HBM GiB | regen |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for c in cells:
        regen = ",".join(r["rung"] for r in c.get("regenerations", [])) or "-"
        fits = "" if c.get("fits_hbm", True) else " (!)"
        lines.append(
            f"| {c['arch']} | {c['shape']} | {c['t_compute_s']:.2e} | "
            f"{c['t_memory_s']:.2e} | {c['t_collective_s']:.2e} | "
            f"{c['bound']} | {c['useful_ratio']:.2f} | "
            f"{c['roofline_fraction']:.4f} | "
            f"{c.get('hbm_gib', 0):.1f}{fits} | {regen} |"
        )
    return "\n".join(lines)


def main():
    single = load("single")
    multi = load("multi")
    table = (
        f"{MARK}\n\n**Single-pod (16×16 = 256 chips), "
        f"{len(single)} cells (scan-calibrated):**\n\n"
        + fmt(single)
        + "\n\n**Multi-pod (2×16×16 = 512 chips) feasibility "
        "(uncalibrated — the pod axis shards; roofline terms are "
        "reported on the single-pod table):** all "
        f"{len(multi)} cells lower + compile; per-cell HBM/regen in "
        f"`{DIR}/*_multi.json`.\n"
    )
    src = open(MD).read()
    assert MARK in src
    pre = src.split(MARK)[0]
    post = src.split(MARK)[1]
    # drop any previously inserted table (up to the next section header)
    idx = post.find("\nReading the table:")
    post = post[idx:] if idx >= 0 else post
    open(MD, "w").write(pre + table + post)
    print(f"inserted {len(single)}-row roofline table")


if __name__ == "__main__":
    main()
