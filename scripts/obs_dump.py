"""Run a small traced workload and dump its observability artifacts.

Produces, from one ``PlanEngine`` session with span tracing enabled:

* a Chrome-trace / Perfetto JSON file (``--trace``) — load it at
  https://ui.perfetto.dev or ``chrome://tracing`` to see the request
  path (admission/execute/fallback), the solver phases
  (fuse/enumerate/chunk-merge), store load/save, the frontend trace,
  and (with ``REPRO_OBS_SAMPLE``) sampled per-segment timings — one
  virtual thread row per recording thread;
* a Prometheus text-exposition file (``--metrics``) — the same numbers
  ``PlanEngine.stats()`` reports, in scrape format.

Both artifacts are validated after writing (the trace re-loaded as JSON
and checked for complete events, the exposition parsed line by line);
a validation failure exits nonzero, which is how CI asserts the export
round-trip.

Usage:
    PYTHONPATH=src python scripts/obs_dump.py \
        --trace obs_trace.json --metrics obs_metrics.txt [--requests 8]
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys

_ROOT = pathlib.Path(__file__).resolve().parent.parent
if str(_ROOT) not in sys.path:
    sys.path.insert(0, str(_ROOT))

from benchmarks.bench_obs import (_workload, validate_chrome_trace,
                                  validate_exposition)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--trace", default="obs_trace.json",
                    help="Chrome-trace JSON output path")
    ap.add_argument("--metrics", default="obs_metrics.txt",
                    help="Prometheus text exposition output path")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--budget", type=float, default=2.0,
                    help="solver time budget (seconds)")
    args = ap.parse_args()

    from repro.core.solver import SolverOptions
    from repro.obs import configure, dump_chrome_trace, tracer
    from repro.serve import PlanEngine, ServeConfig

    configure(enabled=True)
    tracer().clear()
    fn, fn_args = _workload()
    eng = PlanEngine(sc=ServeConfig())
    tf = eng.register_function(
        "w", fn, fn_args, solver_opts=SolverOptions(time_budget_s=args.budget))
    if tf is None:
        print("obs_dump: trace/solve failed (degraded mode)", file=sys.stderr)
        return 1
    for _ in range(max(1, args.requests)):
        eng.submit("w", fn_args)

    spans = tracer().snapshot()
    dump_chrome_trace(spans, args.trace)
    text = eng.metrics.expose()
    with open(args.metrics, "w") as f:
        f.write(text)
    eng.shutdown()
    configure(enabled=False)

    # round-trip validation: re-read what was written, as a consumer would
    with open(args.trace) as f:
        doc = json.load(f)
    trace_problems = validate_chrome_trace(doc)
    with open(args.metrics) as f:
        expo_problems = validate_exposition(f.read())

    cats = sorted({s.cat for s in spans})
    print(f"obs_dump: {len(spans)} spans ({', '.join(cats)}) "
          f"-> {args.trace}")
    print(f"obs_dump: {len(text.strip().splitlines())} exposition lines "
          f"-> {args.metrics}")
    problems = [f"trace: {p}" for p in trace_problems] \
        + [f"exposition: {p}" for p in expo_problems]
    if problems:
        for p in problems:
            print(f"obs_dump: INVALID {p}", file=sys.stderr)
        return 1
    print("obs_dump: round-trip valid")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
