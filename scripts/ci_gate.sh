#!/usr/bin/env bash
# ci_gate.sh LABEL MEASURE_CMD GATE_CMD — the one measure/gate/retry
# policy every benchmark-gated CI job shares.
#
# Runs MEASURE_CMD, then GATE_CMD.  Exit codes follow the
# scripts/bench_compare.py contract:
#
#   gate exit 0 -> pass;
#   gate exit 2 -> correctness failure (the CORRECTNESS_TAG contract:
#     miscompile, lost update, broken accounting invariant, resilience
#     breach) -> fail IMMEDIATELY with exit 2 — never re-measured, so an
#     intermittent correctness bug cannot be retried away;
#   any other nonzero -> perf/noise failure -> exactly one re-measure +
#     re-gate (gates run on same-run ratios, robust to runner speed, but
#     shared runners still jitter; a real regression still fails twice).
set -euo pipefail

if [ "$#" -ne 3 ]; then
  echo "usage: $0 LABEL MEASURE_CMD GATE_CMD" >&2
  exit 64
fi

label=$1
measure=$2
gate=$3

bash -euo pipefail -c "$measure"
set +e
bash -euo pipefail -c "$gate"
status=$?
set -e
if [ "$status" -eq 0 ]; then
  exit 0
elif [ "$status" -eq 2 ]; then
  echo "::error::${label}: correctness failure — not retrying"
  exit 2
fi
echo "::warning::${label}: gate failed once; re-measuring"
bash -euo pipefail -c "$measure"
bash -euo pipefail -c "$gate"
